//! Per-figure experiment drivers (paper §4, Appendices D–E).
//!
//! Every driver takes explicit grid parameters so benches can run
//! reduced grids while `examples/paper_figures.rs` runs fuller ones.
//! Rows come back as plain structs; rendering lives in [`super::table`].

use super::grid::{pow2_rounds, GridRun, Series, Snapshot};
use crate::baselines::{guo, rf};
use crate::data::synth::PaperDataset;
use crate::data::{train_test_split, Dataset};
use crate::gbdt::GbdtParams;
use crate::layout::{encode, EncodeOptions, FeatureInfo, PackedModel};
use crate::mcu::{McuSpec, ESP32_S3, NANO_33_BLE};
use crate::metrics::mean_std;
use crate::toad::ToadParams;

/// Subsample + split one paper dataset for a sweep.
pub fn prep(ds: PaperDataset, seed: u64, row_cap: usize) -> (Dataset, Dataset) {
    let full = ds.generate(1000 + seed); // dataset instance fixed per seed
    let n = full.n_rows().min(row_cap);
    let sub = full.select(&(0..n).collect::<Vec<_>>());
    train_test_split(&sub, 0.2, seed)
}

// ------------------------------------------------------------- Figure 4

/// One (series, memory-limit) point of Figure 4.
#[derive(Clone, Debug)]
pub struct Fig4Row {
    pub dataset: &'static str,
    pub series: String,
    pub limit_bytes: usize,
    /// Mean/std of the best reachable score across seeds (NaN mean if
    /// nothing fits at this limit for some seed — those seeds are
    /// skipped, `n` reports how many contributed).
    pub mean: f64,
    pub std: f64,
    pub n: usize,
}

/// Best score among snapshots with `size <= limit`.
fn best_at(snapshots: &[Snapshot], limit: usize) -> Option<f64> {
    snapshots
        .iter()
        .filter(|s| s.size_bytes <= limit)
        .map(|s| s.score)
        .max_by(|a, b| a.partial_cmp(b).unwrap())
}

/// The Figure 4 protocol: per seed, collect candidates over the grid
/// per series; report best-at-limit aggregated over seeds.
#[allow(clippy::too_many_arguments)]
pub fn fig4_rows(
    ds: PaperDataset,
    seeds: &[u64],
    depths: &[usize],
    log_max_rounds: u32,
    penalty_grid: &[(f64, f64)],
    limits: &[usize],
    row_cap: usize,
) -> Vec<Fig4Row> {
    let rounds = pow2_rounds(log_max_rounds);
    let base_series = [
        Series::ToadPlain,
        Series::ToadOblivious,
        Series::LgbmF32,
        Series::LgbmQ16,
        Series::LgbmArray,
    ];
    let extra = [
        Series::Cegb { feature_cost: 2.0, split_cost: 0.1 },
        Series::Ccp { alpha: 0.01 },
    ];

    // candidates[series_label][seed] -> snapshots
    let mut candidates: Vec<(String, Vec<Vec<Snapshot>>)> = Vec::new();
    let mut series_labels: Vec<String> = Vec::new();
    let mut push = |label: String, per_seed: Vec<Vec<Snapshot>>| {
        series_labels.push(label.clone());
        candidates.push((label, per_seed));
    };

    // Penalized ToaD: union of the penalty grid (best-at-limit over all).
    let mut toad_pen: Vec<Vec<Snapshot>> = vec![Vec::new(); seeds.len()];
    for (si, &seed) in seeds.iter().enumerate() {
        let (tr, te) = prep(ds, seed, row_cap);
        for &depth in depths {
            for &(iota, xi) in penalty_grid {
                let snaps =
                    GridRun::run(&tr, &te, Series::ToadPenalized { iota, xi }, depth, &rounds);
                toad_pen[si].extend(snaps);
            }
        }
    }
    push("toad(penalized)".into(), toad_pen);

    for series in base_series.into_iter().chain(extra) {
        let mut per_seed: Vec<Vec<Snapshot>> = vec![Vec::new(); seeds.len()];
        for (si, &seed) in seeds.iter().enumerate() {
            let (tr, te) = prep(ds, seed, row_cap);
            for &depth in depths {
                per_seed[si].extend(GridRun::run(&tr, &te, series, depth, &rounds));
            }
        }
        push(series.label(), per_seed);
    }

    let mut rows = Vec::new();
    for (label, per_seed) in &candidates {
        for &limit in limits {
            let scores: Vec<f64> =
                per_seed.iter().filter_map(|snaps| best_at(snaps, limit)).collect();
            let (mean, std) = mean_std(&scores);
            rows.push(Fig4Row {
                dataset: ds.name(),
                series: label.clone(),
                limit_bytes: limit,
                mean,
                std,
                n: scores.len(),
            });
        }
    }
    rows
}

// ------------------------------------------------- Figures 5/7 (multivariate)

/// One (ι, ξ) cell of the multivariate grids.
#[derive(Clone, Debug)]
pub struct MultiRow {
    pub iota: f64,
    pub xi: f64,
    pub size_bytes: usize,
    pub score: f64,
}

/// Figure 5/7 driver: one model per (ι, ξ) at fixed rounds/depth.
pub fn multivariate_rows(
    ds: PaperDataset,
    seed: u64,
    iotas: &[f64],
    xis: &[f64],
    rounds: usize,
    depth: usize,
    row_cap: usize,
) -> Vec<MultiRow> {
    let (tr, te) = prep(ds, seed, row_cap);
    let mut rows = Vec::with_capacity(iotas.len() * xis.len());
    for &iota in iotas {
        for &xi in xis {
            let snaps =
                GridRun::run(&tr, &te, Series::ToadPenalized { iota, xi }, depth, &[rounds]);
            let s = &snaps[0];
            rows.push(MultiRow { iota, xi, size_bytes: s.size_bytes, score: s.score });
        }
    }
    rows
}

/// Figure 5 driver: like [`multivariate_rows`] but trains each (ι, ξ)
/// under a fixed `toad_forestsize` byte budget ("the maximum memory
/// size is fixed, allowing for an unlimited number of trees and
/// nodes", paper §4.2.1), which is the semantics Figure 5 reports.
#[allow(clippy::too_many_arguments)]
pub fn multivariate_budget_rows(
    ds: PaperDataset,
    seed: u64,
    iotas: &[f64],
    xis: &[f64],
    max_rounds: usize,
    depth: usize,
    budget_bytes: usize,
    row_cap: usize,
) -> Vec<MultiRow> {
    let (tr, te) = prep(ds, seed, row_cap);
    let mut rows = Vec::with_capacity(iotas.len() * xis.len());
    for &iota in iotas {
        for &xi in xis {
            let mut params = ToadParams::new(GbdtParams::paper(max_rounds, depth), iota, xi);
            params.forestsize_bytes = Some(budget_bytes);
            let m = crate::toad::train_toad_with_budget(&tr, &params);
            rows.push(MultiRow {
                iota,
                xi,
                size_bytes: m.size_bytes(),
                score: m.model.score(&te),
            });
        }
    }
    rows
}

// ------------------------------------------------- Figure 6 (univariate)

/// Which penalty the univariate sweep varies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PenaltyKind {
    Feature,
    Threshold,
}

/// One point of the univariate sensitivity analysis.
#[derive(Clone, Debug)]
pub struct UniRow {
    pub penalty: f64,
    pub score: f64,
    pub n_features: usize,
    pub n_global_values: usize,
    pub reuse_factor: f64,
}

/// Figure 6 / Appendix E.2 driver.
pub fn univariate_rows(
    ds: PaperDataset,
    seed: u64,
    kind: PenaltyKind,
    values: &[f64],
    rounds: usize,
    depth: usize,
    row_cap: usize,
) -> Vec<UniRow> {
    let (tr, te) = prep(ds, seed, row_cap);
    values
        .iter()
        .map(|&v| {
            let (iota, xi) = match kind {
                PenaltyKind::Feature => (v, 0.0),
                PenaltyKind::Threshold => (0.0, v),
            };
            let snaps =
                GridRun::run(&tr, &te, Series::ToadPenalized { iota, xi }, depth, &[rounds]);
            let s = &snaps[0];
            UniRow {
                penalty: v,
                score: s.score,
                n_features: s.stats.n_features_used,
                n_global_values: s.stats.n_global_values(),
                reuse_factor: s.stats.reuse_factor(),
            }
        })
        .collect()
}

/// [`univariate_rows`] over a sparse (libsvm-loaded) train/test pair:
/// the same penalty grid and reported statistics, but trained through
/// [`crate::gbdt::train_sparse_with_penalty`] and scored through
/// [`crate::inference::QuantizedFlatModel::score_sparse`], so neither
/// side ever materializes a dense float matrix. `test` must have at
/// most as many features as `train` after
/// [`crate::data::SparseDataset::pad_features`] alignment (the CLI
/// pads before calling).
pub fn univariate_rows_sparse(
    train: &crate::data::SparseDataset,
    test: &crate::data::SparseDataset,
    kind: PenaltyKind,
    values: &[f64],
    rounds: usize,
    depth: usize,
) -> Vec<UniRow> {
    values
        .iter()
        .map(|&v| {
            let (iota, xi) = match kind {
                PenaltyKind::Feature => (v, 0.0),
                PenaltyKind::Threshold => (0.0, v),
            };
            let penalty = crate::toad::ToadPenalty::new(train.n_features(), iota, xi);
            let (model, _) = crate::gbdt::train_sparse_with_penalty(
                train,
                GbdtParams::paper(rounds, depth),
                penalty,
            );
            let stats = crate::toad::ReuseStats::from_model(&model);
            let score = model.quantize().score_sparse(test);
            UniRow {
                penalty: v,
                score,
                n_features: stats.n_features_used,
                n_global_values: stats.n_global_values(),
                reuse_factor: stats.reuse_factor(),
            }
        })
        .collect()
}

// ------------------------------------------------- Figure 8 (RF comparison)

/// One (series, limit) point of the Appendix D comparison.
#[derive(Clone, Debug)]
pub struct Fig8Row {
    pub dataset: &'static str,
    pub series: String,
    pub limit_bytes: usize,
    pub mean: f64,
    pub std: f64,
    pub n: usize,
}

/// Appendix D / Figure 8: boosted methods vs RF and Guo-pruned RF.
/// Classification datasets only; tree budget capped at 256.
pub fn fig8_rows(
    ds: PaperDataset,
    seeds: &[u64],
    depths: &[usize],
    limits: &[usize],
    row_cap: usize,
) -> Vec<Fig8Row> {
    assert!(ds.task().is_classification(), "fig8 is classification-only");
    let rounds = pow2_rounds(8); // up to 256 trees, as in the appendix
    let mut out = Vec::new();

    // Boosted series reuse the Figure 4 machinery.
    for series in [Series::ToadPenalized { iota: 2.0, xi: 1.0 }, Series::LgbmF32] {
        let mut per_seed: Vec<Vec<Snapshot>> = vec![Vec::new(); seeds.len()];
        for (si, &seed) in seeds.iter().enumerate() {
            let (tr, te) = prep(ds, seed, row_cap);
            for &depth in depths {
                per_seed[si].extend(GridRun::run(&tr, &te, series, depth, &rounds));
            }
        }
        for &limit in limits {
            let scores: Vec<f64> =
                per_seed.iter().filter_map(|s| best_at(s, limit)).collect();
            let (mean, std) = mean_std(&scores);
            out.push(Fig8Row {
                dataset: ds.name(),
                series: if matches!(series, Series::LgbmF32) {
                    "lgbm_f32".into()
                } else {
                    "toad(penalized)".into()
                },
                limit_bytes: limit,
                mean,
                std,
                n: scores.len(),
            });
        }
    }

    // RF + Guo-pruned RF: prefixes of a 256-tree forest.
    let mut rf_per_seed: Vec<Vec<(usize, f64)>> = Vec::new(); // (bytes, score)
    let mut guo_per_seed: Vec<Vec<(usize, f64)>> = Vec::new();
    for &seed in seeds {
        let (tr_all, te) = prep(ds, seed, row_cap);
        let (tr, prune_set) = train_test_split(&tr_all, 0.25, seed ^ 0x9);
        let forest = rf::train_rf(
            &tr,
            rf::RfParams { n_trees: 256, max_depth: 8, seed, ..Default::default() },
        );
        let order = guo::order_trees(&forest, &prune_set, 0.5);
        let mut rf_points = Vec::new();
        let mut guo_points = Vec::new();
        for &k in &rounds {
            let natural = forest.subensemble(&(0..k).collect::<Vec<_>>());
            rf_points.push((natural.pointer_f32_bytes(), natural.score(&te)));
            let pruned = forest.subensemble(&order[..k]);
            guo_points.push((pruned.pointer_f32_bytes(), pruned.score(&te)));
        }
        rf_per_seed.push(rf_points);
        guo_per_seed.push(guo_points);
    }
    for (label, per_seed) in [("rf", &rf_per_seed), ("rf_guo_pruned", &guo_per_seed)] {
        for &limit in limits {
            let scores: Vec<f64> = per_seed
                .iter()
                .filter_map(|points| {
                    points
                        .iter()
                        .filter(|(b, _)| *b <= limit)
                        .map(|(_, s)| *s)
                        .max_by(|a, b| a.partial_cmp(b).unwrap())
                })
                .collect();
            let (mean, std) = mean_std(&scores);
            out.push(Fig8Row {
                dataset: ds.name(),
                series: label.into(),
                limit_bytes: limit,
                mean,
                std,
                n: scores.len(),
            });
        }
    }
    out
}

// ------------------------------------------- Adaptive early-exit curve

/// One tolerance point of the accuracy-vs-work curve for the
/// margin-bounded early-exit engine
/// ([`crate::inference::AdaptivePolicy`]).
#[derive(Clone, Debug)]
pub struct AdaptiveRow {
    /// Exit tolerance (`0.0` is the unarmed/exact engine).
    pub eps: f32,
    /// Task metric under `Margin(eps)`.
    pub score: f64,
    /// Mean trees evaluated per row at this tolerance.
    pub mean_trees: f64,
    /// Task metric of the exact engine (constant across the grid).
    pub exact_score: f64,
    /// Full ensemble depth (the ceiling for `mean_trees`).
    pub n_trees: usize,
}

/// The accuracy-vs-mean-trees-evaluated curve: train once, then sweep
/// the exit tolerance over `eps_grid` through the adaptive engine. One
/// model, one quantization — every point differs only in the serving
/// policy, which is exactly the deployment question the curve answers
/// (how much descent work a device class can skip at a given accuracy
/// target).
pub fn adaptive_rows(
    ds: PaperDataset,
    seed: u64,
    rounds: usize,
    depth: usize,
    eps_grid: &[f32],
    row_cap: usize,
) -> Vec<AdaptiveRow> {
    use crate::inference::{AdaptivePolicy, Predictor};
    let (tr, te) = prep(ds, seed, row_cap);
    let model = crate::gbdt::booster::train(&tr, GbdtParams::paper(rounds, depth));
    let quant = model.quantize();
    let n_trees = Predictor::n_trees(&quant);
    let exact_score = Predictor::score(&quant, &te);
    eps_grid
        .iter()
        .map(|&eps| {
            let a = Predictor::score_adaptive(&quant, &te, AdaptivePolicy::Margin(eps));
            AdaptiveRow { eps, score: a.score, mean_trees: a.mean_trees, exact_score, n_trees }
        })
        .collect()
}

// ------------------------------------------------- Table 2 (latency)

/// One hardware row of Table 2.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub hardware: &'static str,
    pub toad_us: f64,
    pub lgbm_us: f64,
    pub slowdown: f64,
}

/// Appendix E.1 / Table 2: per-prediction latency of the bit-packed
/// ToaD interpreter vs a pointer-layout traversal, on the MCU cycle
/// model (DESIGN.md §5 hardware substitution). The model matches the
/// paper's setup: Covertype-binary at a 0.5 KB budget (4 trees, depth 4).
pub fn table2_rows(seed: u64, row_cap: usize) -> (Vec<Table2Row>, PackedModel, Dataset) {
    let (tr, te) = prep(PaperDataset::CovertypeBinary, seed, row_cap);
    let mut params = ToadParams::new(GbdtParams::paper(4, 4), 2.0, 1.0);
    params.forestsize_bytes = Some(512);
    let m = crate::toad::train_toad_with_budget(&tr, &params);
    let finfo = FeatureInfo::from_dataset(&tr);
    let blob = encode(&m.model, &finfo, &EncodeOptions::default())
        .expect("table 2 models fit the layout's header fields");
    let packed = PackedModel::from_bytes(blob);
    let probe = te.row(0);
    let rows = [ESP32_S3, NANO_33_BLE]
        .iter()
        .map(|spec: &McuSpec| {
            let toad_s = spec.toad_latency(&packed, &probe);
            let lgbm_s = spec.pointer_latency(&packed, &probe);
            Table2Row {
                hardware: spec.name,
                toad_us: toad_s * 1e6,
                lgbm_us: lgbm_s * 1e6,
                slowdown: toad_s / lgbm_s,
            }
        })
        .collect();
    (rows, packed, te)
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;

    #[test]
    fn fig4_reduced_grid_shapes() {
        let limits = [512usize, 2048, 8192];
        let rows = fig4_rows(
            PaperDataset::BreastCancer,
            &[1, 2],
            &[2],
            3,
            &[(2.0, 1.0)],
            &limits,
            400,
        );
        // 8 series × 3 limits
        assert_eq!(rows.len(), 8 * 3);
        // At a generous limit every series must reach a decent score.
        for r in rows.iter().filter(|r| r.limit_bytes == 8192) {
            assert!(r.n == 2, "{}: {} seeds", r.series, r.n);
            assert!(r.mean > 0.8, "{} mean {}", r.series, r.mean);
        }
        // ToaD at the tightest limit should not trail the f32 pointer
        // baseline (it fits strictly more model into the budget).
        let toad = rows
            .iter()
            .find(|r| r.series == "toad(penalized)" && r.limit_bytes == 512)
            .unwrap();
        let lgbm = rows.iter().find(|r| r.series == "lgbm_f32" && r.limit_bytes == 512).unwrap();
        assert!(
            toad.mean >= lgbm.mean - 0.02,
            "toad {} vs lgbm {} at 512B",
            toad.mean,
            lgbm.mean
        );
    }

    #[test]
    fn univariate_threshold_penalty_reduces_values() {
        let rows = univariate_rows(
            PaperDataset::BreastCancer,
            1,
            PenaltyKind::Threshold,
            &[0.0, 1.0, 64.0, 4096.0],
            16,
            2,
            400,
        );
        assert!(rows.last().unwrap().n_global_values < rows[0].n_global_values);
    }

    #[test]
    fn multivariate_grid_dimensions() {
        let rows = multivariate_rows(
            PaperDataset::CaliforniaHousing,
            1,
            &[0.0, 8.0],
            &[0.0, 8.0],
            8,
            2,
            1000,
        );
        assert_eq!(rows.len(), 4);
        // More penalty, less (or equal) memory.
        let free = rows.iter().find(|r| r.iota == 0.0 && r.xi == 0.0).unwrap();
        let heavy = rows.iter().find(|r| r.iota == 8.0 && r.xi == 8.0).unwrap();
        assert!(heavy.size_bytes <= free.size_bytes);
    }

    #[test]
    fn adaptive_curve_trades_work_for_tolerance() {
        let rows = adaptive_rows(PaperDataset::Mushroom, 1, 16, 2, &[0.0, 1e-6, 0.5, 4.0], 600);
        assert_eq!(rows.len(), 4);
        // eps = 0 is the unarmed engine: exact metric at full depth.
        assert_eq!(rows[0].score, rows[0].exact_score);
        assert_eq!(rows[0].mean_trees, rows[0].n_trees as f64);
        // Work is monotone nonincreasing in the tolerance: a larger eps
        // only widens every exit condition.
        for w in rows.windows(2) {
            assert!(
                w[1].mean_trees <= w[0].mean_trees,
                "mean_trees must not grow with eps: {} -> {}",
                w[0].mean_trees,
                w[1].mean_trees
            );
        }
        // A separable task with an armed tolerance must shed real work
        // without giving up the metric at tiny eps.
        assert!(rows[1].mean_trees < rows[1].n_trees as f64, "no early exit at eps=1e-6");
        assert!((rows[1].score - rows[1].exact_score).abs() < 1e-9);
    }

    #[test]
    fn table2_slowdown_band() {
        let (rows, packed, _) = table2_rows(1, 3000);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.toad_us > r.lgbm_us, "{}: toad must be slower", r.hardware);
            assert!((2.0..=15.0).contains(&r.slowdown), "{}: slowdown {}", r.hardware, r.slowdown);
        }
        assert!(packed.size_bytes() <= 512);
    }
}
