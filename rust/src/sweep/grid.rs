//! Snapshotting grid runs.
//!
//! The paper's grid (§4) crosses iterations 2^0..2^10 with depths
//! 2^0..2^3 and penalty values. Training a separate model per iteration
//! count would waste a factor of ~11: a boosting run's K-round prefix
//! *is* the K-round model. [`GridRun`] therefore boosts once per
//! (method, depth, penalty) configuration and snapshots score + sizes
//! at each requested round.

use crate::baselines::ccp;
use crate::baselines::cegb::CegbPenalty;
use crate::data::{Dataset, Task};
use crate::gbdt::booster::{Booster, GbdtParams};
use crate::gbdt::loss::Objective;
use crate::gbdt::splitter::{NoPenalty, SplitPenalty};
use crate::gbdt::{GbdtModel, Tree};
use crate::layout::{baseline, encode, EncodeOptions, FeatureInfo};
use crate::toad::{ReuseStats, ToadPenalty};

/// A method series of the Figure 4 comparison. The three LightGBM size
/// accountings (`LgbmF32`, `LgbmQ16`, `LgbmArray`) and `ToadPlain`
/// share one unpenalized training run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Series {
    /// ToaD layout + reuse penalties.
    ToadPenalized { iota: f64, xi: f64 },
    /// ToaD layout, ι = ξ = 0.
    ToadPlain,
    /// Oblivious (level-shared) growth in the ToaD layout: every tree
    /// stores the compact oblivious body (d pairs + 2^d leaves), the
    /// extreme end of the size frontier.
    ToadOblivious,
    /// float32 pointer layout (128 bits/node).
    LgbmF32,
    /// fp16-quantized pointer layout (64 bits/node); score measured on
    /// the quantized model.
    LgbmQ16,
    /// pointer-less array layout at float32.
    LgbmArray,
    /// Cost-efficient gradient boosting (pointer f32 accounting).
    Cegb { feature_cost: f64, split_cost: f64 },
    /// Per-tree cost-complexity pruning (pointer f32 accounting).
    Ccp { alpha: f64 },
}

impl Series {
    pub fn label(&self) -> String {
        match self {
            Series::ToadPenalized { iota, xi } => format!("toad(i={iota},x={xi})"),
            Series::ToadPlain => "toad(plain)".into(),
            Series::ToadOblivious => "toad(oblivious)".into(),
            Series::LgbmF32 => "lgbm_f32".into(),
            Series::LgbmQ16 => "lgbm_q16".into(),
            Series::LgbmArray => "lgbm_array".into(),
            Series::Cegb { .. } => "cegb".into(),
            Series::Ccp { .. } => "ccp".into(),
        }
    }
}

/// One measured point of a grid run.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub rounds: usize,
    pub score: f64,
    pub size_bytes: usize,
    pub stats: ReuseStats,
}

/// Incremental test-set evaluation: raw scores updated tree by tree.
struct TestEval {
    rows: Vec<Vec<f32>>,
    raw: Vec<Vec<f64>>, // [output][row]
    objective: Objective,
}

impl TestEval {
    fn new(test: &Dataset, base: &[f64], objective: Objective) -> TestEval {
        let rows: Vec<Vec<f32>> = (0..test.n_rows()).map(|i| test.row(i)).collect();
        let raw = base.iter().map(|&b| vec![b; rows.len()]).collect();
        TestEval { rows, raw, objective }
    }

    fn add_tree(&mut self, output: usize, tree: &Tree) {
        for (i, row) in self.rows.iter().enumerate() {
            self.raw[output][i] += tree.predict_row(row);
        }
    }

    fn score(&self, test: &Dataset) -> f64 {
        match test.task {
            Task::Regression => {
                crate::metrics::r2_score(&test.targets, &self.raw[0])
            }
            _ => {
                let preds: Vec<usize> = (0..self.rows.len())
                    .map(|i| {
                        let raw_row: Vec<f64> =
                            (0..self.raw.len()).map(|k| self.raw[k][i]).collect();
                        self.objective.predict_class(&raw_row)
                    })
                    .collect();
                crate::metrics::accuracy(&test.labels, &preds)
            }
        }
    }
}

/// Quantize thresholds and leaf values of a model to fp16 (the paper's
/// "quantized LightGBM" baseline).
pub fn quantize_f16(model: &GbdtModel) -> GbdtModel {
    use crate::bitio::{f16_bits_to_f32, f32_to_f16_bits};
    use crate::gbdt::tree::Node;
    let mut q = model.clone();
    for tree in q.trees.iter_mut().flatten() {
        for node in &mut tree.nodes {
            match node {
                Node::Internal { threshold, .. } => {
                    *threshold = f16_bits_to_f32(f32_to_f16_bits(*threshold));
                }
                Node::Leaf { value } => {
                    *value = f16_bits_to_f32(f32_to_f16_bits(*value as f32)) as f64;
                }
            }
        }
    }
    q
}

/// Run one boosting configuration and snapshot the requested rounds.
///
/// `snap_rounds` must be ascending (e.g. `[1, 2, 4, …, 1024]`).
pub struct GridRun;

impl GridRun {
    pub fn run(
        train: &Dataset,
        test: &Dataset,
        series: Series,
        depth: usize,
        snap_rounds: &[usize],
    ) -> Vec<Snapshot> {
        let max_rounds = *snap_rounds.last().expect("non-empty rounds");
        let params = GbdtParams::paper(max_rounds, depth);
        match series {
            Series::ToadPenalized { iota, xi } => {
                let pen = ToadPenalty::new(train.n_features(), iota, xi);
                Self::boost_and_snapshot(train, test, params, pen, snap_rounds, series)
            }
            Series::ToadPlain | Series::LgbmF32 | Series::LgbmQ16 | Series::LgbmArray => {
                Self::boost_and_snapshot(train, test, params, NoPenalty, snap_rounds, series)
            }
            Series::ToadOblivious => {
                let params =
                    GbdtParams { growth: crate::gbdt::GrowthMode::Oblivious, ..params };
                Self::boost_and_snapshot(train, test, params, NoPenalty, snap_rounds, series)
            }
            Series::Cegb { feature_cost, split_cost } => {
                let pen = CegbPenalty::uniform(train.n_features(), feature_cost, split_cost);
                Self::boost_and_snapshot(train, test, params, pen, snap_rounds, series)
            }
            Series::Ccp { alpha } => {
                Self::boost_ccp_and_snapshot(train, test, params, alpha, snap_rounds)
            }
        }
    }

    fn size_of(series: Series, model: &GbdtModel, finfo: &[FeatureInfo]) -> usize {
        match series {
            Series::ToadPenalized { .. } | Series::ToadPlain | Series::ToadOblivious => {
                // Measure the actual packed blob rather than the size plan so
                // the frontier cannot drift from the format: oblivious trees
                // pay exactly their encoded d (feature, threshold) records
                // plus the 2^d leaf table, classic trees their node records.
                encode(model, finfo, &EncodeOptions::default())
                    .expect("sweep-trained models fit the ToaD header fields")
                    .len()
            }
            Series::LgbmF32 | Series::Cegb { .. } | Series::Ccp { .. } => {
                baseline::pointer_f32_bytes(model)
            }
            Series::LgbmQ16 => baseline::pointer_f16_bytes(model),
            Series::LgbmArray => baseline::array_f32_bytes(model),
        }
    }

    fn boost_and_snapshot<P: SplitPenalty>(
        train: &Dataset,
        test: &Dataset,
        params: GbdtParams,
        penalty: P,
        snap_rounds: &[usize],
        series: Series,
    ) -> Vec<Snapshot> {
        let finfo = FeatureInfo::from_dataset(train);
        let mut booster = Booster::new(train, params, penalty);
        let objective = booster.model().objective;
        let quantized = matches!(series, Series::LgbmQ16);
        let mut eval = TestEval::new(test, &booster.model().base_scores, objective);
        let mut snapshots = Vec::with_capacity(snap_rounds.len());
        let mut next = 0usize;
        for round in 1..=params.n_rounds {
            let any_split = booster.boost_round();
            // Feed the new trees (one per output) into the test eval.
            for k in 0..booster.model().n_outputs() {
                let tree = &booster.model().trees[k][round - 1];
                if quantized {
                    let mut m1 = GbdtModel {
                        objective,
                        base_scores: vec![0.0],
                        trees: vec![vec![tree.clone()]],
                        n_features: train.n_features(),
                        name: String::new(),
                    };
                    m1 = quantize_f16(&m1);
                    eval.add_tree(k, &m1.trees[0][0]);
                } else {
                    eval.add_tree(k, tree);
                }
            }
            while next < snap_rounds.len() && snap_rounds[next] == round {
                let model = booster.model();
                let size = if quantized {
                    Self::size_of(series, model, &finfo)
                } else {
                    Self::size_of(series, model, &finfo)
                };
                snapshots.push(Snapshot {
                    rounds: round,
                    score: eval.score(test),
                    size_bytes: size,
                    stats: ReuseStats::from_model(model),
                });
                next += 1;
            }
            if !any_split {
                break; // every later round would be an identical bare leaf
            }
        }
        // Early stop: the remaining snapshot rounds equal the final state.
        if next < snap_rounds.len() {
            let model = booster.model();
            let size = Self::size_of(series, model, &finfo);
            let score = eval.score(test);
            let stats = ReuseStats::from_model(model);
            for &r in &snap_rounds[next..] {
                snapshots.push(Snapshot { rounds: r, score, size_bytes: size, stats: stats.clone() });
            }
        }
        snapshots
    }

    fn boost_ccp_and_snapshot(
        train: &Dataset,
        test: &Dataset,
        params: GbdtParams,
        alpha: f64,
        snap_rounds: &[usize],
    ) -> Vec<Snapshot> {
        let finfo = FeatureInfo::from_dataset(train);
        let lambda = params.lambda;
        let scale = params.learning_rate;
        let mut booster = Booster::new(train, params, NoPenalty);
        let objective = booster.model().objective;
        let mut eval = TestEval::new(test, &booster.model().base_scores, objective);
        let mut snapshots = Vec::with_capacity(snap_rounds.len());
        let mut next = 0usize;
        for round in 1..=params.n_rounds {
            let any_split = booster.boost_round_map(|binned, grad, hess, tree| {
                ccp::prune_tree(&tree, binned, grad, hess, lambda, scale, alpha)
            });
            for k in 0..booster.model().n_outputs() {
                let tree = booster.model().trees[k][round - 1].clone();
                eval.add_tree(k, &tree);
            }
            while next < snap_rounds.len() && snap_rounds[next] == round {
                let model = booster.model();
                snapshots.push(Snapshot {
                    rounds: round,
                    score: eval.score(test),
                    size_bytes: Self::size_of(Series::Ccp { alpha }, model, &finfo),
                    stats: ReuseStats::from_model(model),
                });
                next += 1;
            }
            if !any_split {
                break;
            }
        }
        if next < snap_rounds.len() {
            let model = booster.model();
            let size = Self::size_of(Series::Ccp { alpha }, model, &finfo);
            let score = eval.score(test);
            let stats = ReuseStats::from_model(model);
            for &r in &snap_rounds[next..] {
                snapshots.push(Snapshot { rounds: r, score, size_bytes: size, stats: stats.clone() });
            }
        }
        snapshots
    }
}

/// Powers of two `2^0..=2^log_max`, the paper's iteration grid.
pub fn pow2_rounds(log_max: u32) -> Vec<usize> {
    (0..=log_max).map(|e| 1usize << e).collect()
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::data::train_test_split;

    fn data() -> (Dataset, Dataset) {
        let d = PaperDataset::BreastCancer.generate(91).select(&(0..400).collect::<Vec<_>>());
        train_test_split(&d, 0.2, 1)
    }

    #[test]
    fn snapshots_match_direct_training() {
        let (tr, te) = data();
        let snaps = GridRun::run(&tr, &te, Series::ToadPlain, 2, &[1, 4, 8]);
        assert_eq!(snaps.len(), 3);
        // Direct 4-round training must agree with the 4-round snapshot.
        let direct = crate::gbdt::booster::train(&tr, GbdtParams::paper(4, 2));
        let want = direct.score(&te);
        assert!(
            (snaps[1].score - want).abs() < 1e-9,
            "prefix snapshot {} vs direct {}",
            snaps[1].score,
            want
        );
        // Sizes grow with rounds.
        assert!(snaps[0].size_bytes <= snaps[1].size_bytes);
        assert!(snaps[1].size_bytes <= snaps[2].size_bytes);
    }

    #[test]
    fn series_size_orderings() {
        let (tr, te) = data();
        let f32s = GridRun::run(&tr, &te, Series::LgbmF32, 2, &[8]);
        let q16s = GridRun::run(&tr, &te, Series::LgbmQ16, 2, &[8]);
        let arrs = GridRun::run(&tr, &te, Series::LgbmArray, 2, &[8]);
        let toad = GridRun::run(&tr, &te, Series::ToadPlain, 2, &[8]);
        assert_eq!(q16s[0].size_bytes * 2, f32s[0].size_bytes);
        assert!(arrs[0].size_bytes <= f32s[0].size_bytes, "array strips pointers");
        assert!(
            toad[0].size_bytes < arrs[0].size_bytes,
            "toad {} must undercut array {}",
            toad[0].size_bytes,
            arrs[0].size_bytes
        );
    }

    #[test]
    fn penalized_run_is_smaller() {
        let (tr, te) = data();
        let plain = GridRun::run(&tr, &te, Series::ToadPlain, 2, &[16]);
        let pen = GridRun::run(
            &tr,
            &te,
            Series::ToadPenalized { iota: 4.0, xi: 2.0 },
            2,
            &[16],
        );
        assert!(pen[0].size_bytes <= plain[0].size_bytes);
        assert!(pen[0].stats.n_thresholds <= plain[0].stats.n_thresholds);
    }

    #[test]
    fn quantized_score_close_to_exact() {
        let (tr, te) = data();
        let f32s = GridRun::run(&tr, &te, Series::LgbmF32, 2, &[8]);
        let q16s = GridRun::run(&tr, &te, Series::LgbmQ16, 2, &[8]);
        assert!((f32s[0].score - q16s[0].score).abs() < 0.05);
    }

    #[test]
    fn ccp_and_cegb_series_run() {
        let (tr, te) = data();
        let ccp = GridRun::run(&tr, &te, Series::Ccp { alpha: 0.01 }, 3, &[4]);
        let cegb = GridRun::run(
            &tr,
            &te,
            Series::Cegb { feature_cost: 1.0, split_cost: 0.1 },
            3,
            &[4],
        );
        assert!(ccp[0].score > 0.5);
        assert!(cegb[0].score > 0.5);
    }

    #[test]
    fn oblivious_series_trains_level_uniform_and_scores() {
        let (tr, te) = data();
        let obl = GridRun::run(&tr, &te, Series::ToadOblivious, 2, &[8]);
        assert_eq!(obl.len(), 1);
        assert!(obl[0].score > 0.8, "oblivious accuracy {} too low", obl[0].score);
        assert!(obl[0].size_bytes > 0);
        // At equal depth and rounds the oblivious body (d pairs per
        // tree) stores strictly fewer split references than a complete
        // leaf-wise tree (2^d − 1), so the per-tree payload can only
        // shrink; sanity-check the end-to-end size stays in the same
        // ballpark as plain ToaD rather than exploding.
        let plain = GridRun::run(&tr, &te, Series::ToadPlain, 2, &[8]);
        assert!(
            obl[0].size_bytes <= plain[0].size_bytes * 2,
            "oblivious {} vs plain {}",
            obl[0].size_bytes,
            plain[0].size_bytes
        );
    }

    #[test]
    fn pow2_grid() {
        assert_eq!(pow2_rounds(3), vec![1, 2, 4, 8]);
        assert_eq!(pow2_rounds(0), vec![1]);
    }

    #[test]
    fn quantize_f16_changes_precision_only() {
        let (tr, _) = data();
        let m = crate::gbdt::booster::train(&tr, GbdtParams::paper(4, 2));
        let q = quantize_f16(&m);
        assert_eq!(m.n_trees(), q.n_trees());
        for (a, b) in m.trees[0].iter().zip(&q.trees[0]) {
            assert_eq!(a.n_nodes(), b.n_nodes());
        }
    }
}
