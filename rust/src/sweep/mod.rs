//! The experiment sweep harness: regenerates every table and figure of
//! the paper's evaluation (see DESIGN.md §3 for the index).
//!
//! * [`grid`] — snapshotting grid runs: one boosting run per
//!   (method, depth, penalties) yields *every* iteration count in the
//!   grid (a K-round prefix of a boosting run is exactly the K-round
//!   run, because boosting is incremental and the reuse registries grow
//!   monotonically).
//! * [`figures`] — per-figure drivers (Fig. 4–8, Table 2, appendices).
//! * [`table`] — plain-text/TSV row emission shared by benches.

pub mod figures;
pub mod grid;
pub mod table;

pub use grid::{GridRun, Series, Snapshot};
