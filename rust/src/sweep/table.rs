//! Minimal table rendering for bench output (no external crates).

/// Render an aligned plain-text table.
pub fn render(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<String>, widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:w$}", c, w = widths.get(i).copied().unwrap_or(0)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    out.push_str(&fmt_row(headers.iter().map(|s| s.to_string()).collect(), &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row.clone(), &widths));
        out.push('\n');
    }
    out
}

/// Tab-separated rendering (machine-readable dump next to the table).
pub fn tsv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = headers.join("\t");
    out.push('\n');
    for row in rows {
        out.push_str(&row.join("\t"));
        out.push('\n');
    }
    out
}

/// Human-readable byte size (KB with 2 decimals below 1 MB).
pub fn human_bytes(b: usize) -> String {
    if b < 1024 {
        format!("{b}B")
    } else if b < 1024 * 1024 {
        format!("{:.2}KB", b as f64 / 1024.0)
    } else {
        format!("{:.2}MB", b as f64 / (1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let s = render(
            &["name", "v"],
            &[vec!["a".into(), "1".into()], vec!["longer".into(), "22".into()]],
        );
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("longer"));
        // every data line has the same column offset for the 2nd field
        let off0 = lines[2].find('1').unwrap();
        let off1 = lines[3].find("22").unwrap();
        assert_eq!(off0, off1);
    }

    #[test]
    fn tsv_format() {
        let s = tsv(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(s, "a\tb\n1\t2\n");
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(2048), "2.00KB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00MB");
    }
}
