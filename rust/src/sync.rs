//! Synchronization shim: `std::sync` in production, `loom` under model
//! checking.
//!
//! The concurrent serving tier (`coordinator::{metrics, registry,
//! batcher}`) imports every lock, condvar, and atomic through this
//! module instead of `std::sync` directly. A normal build re-exports
//! the `std` types unchanged — zero overhead, zero dependencies. A
//! build with `RUSTFLAGS="--cfg loom"` swaps in [loom]'s instrumented
//! twins, under which the `loom_` tests exhaustively explore every
//! thread interleaving (and every allowed relaxed-memory outcome) of
//! the serving tier's lock-free protocols:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release loom_
//! ```
//!
//! [loom]: https://docs.rs/loom
//!
//! Two deliberate exceptions stay on `std` under both cfgs:
//!
//! * [`Arc`] — loom's `Arc` exists to catch code relying on the
//!   release/acquire edges of the reference count itself. The serving
//!   tier never does: `Arc` is pure shared ownership here, and every
//!   cross-thread handoff is synchronized by a `Mutex`, `RwLock`,
//!   `Condvar`, or tracked atomic. Keeping `std::sync::Arc` lets
//!   loom-instrumented types flow through the rest of the crate
//!   (`FleetServer`, examples, integration tests) without rethreading
//!   every `Arc` consumer.
//! * [`mpsc`] — loom has no channel model. The batcher's reply
//!   channels are one-shot SPSC handoffs whose delivery/disconnect
//!   semantics are `std`'s contract, not ours; the loom batcher models
//!   check the queue/close protocol *around* them (see
//!   `coordinator::batcher`).

#[cfg(not(loom))]
pub use std::sync::atomic;
#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

#[cfg(loom)]
pub use loom::sync::atomic;
#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

pub use std::sync::mpsc;
pub use std::sync::Arc;

/// Timed condvar wait with poison recovery, ignoring the timed-out
/// flag (callers re-check their predicate and deadline anyway).
///
/// Loom's model has no clock, so under `cfg(loom)` this is a plain
/// `wait`: a timed wait is exactly "a wait that may also wake for no
/// reason", and loom already explores the notified wakeup; callers
/// must tolerate both, which is the condition-loop discipline the
/// batcher follows.
#[cfg(not(loom))]
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: std::time::Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, dur) {
        Ok((g, _)) => g,
        Err(e) => e.into_inner().0,
    }
}

/// See the `cfg(not(loom))` twin above.
#[cfg(loom)]
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    _dur: std::time::Duration,
) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}
