//! Test utilities, including the in-repo property-testing framework.

pub mod prop;
