//! A small property-based testing framework (offline `proptest` stand-in).
//!
//! The vendored crate set does not include `proptest`, so the crate
//! carries its own: deterministic PCG-seeded case generation, a
//! configurable case count, and greedy shrinking for failures on
//! integer-vector inputs. Usage:
//!
//! ```no_run
//! # // no_run: doctest binaries don't inherit the xla rpath link flag.
//! use toad::testutil::prop::{run_prop, Gen};
//! run_prop("addition commutes", 100, |g| {
//!     let a = g.u64(1000);
//!     let b = g.u64(1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case receives a fresh [`Gen`] whose draws are reproducible from
//! the printed seed; a failing property panics with the case seed so the
//! exact case can be replayed by passing it to [`replay_prop`].

use crate::data::{Dataset, Task};
use crate::prng::Pcg64;

/// Generator handle passed to properties; wraps a seeded PRNG with
/// convenience draw methods.
pub struct Gen {
    rng: Pcg64,
    /// The seed this case was created from (for failure reporting).
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Self {
        Gen { rng: Pcg64::new(case_seed), case_seed }
    }

    /// Uniform u64 in `[0, bound)` (bound ≥ 1).
    pub fn u64(&mut self, bound: u64) -> u64 {
        self.rng.gen_range(bound as usize) as u64
    }

    /// Uniform usize in `[0, bound)`.
    pub fn usize(&mut self, bound: usize) -> usize {
        self.rng.gen_range(bound)
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.gen_range(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.gen_uniform(lo, hi)
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        self.rng.gen_normal()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.gen_bool(p)
    }

    /// Vector of f32s in `[lo, hi)` with a random length in `[min_len, max_len]`.
    pub fn f32_vec(&mut self, min_len: usize, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| lo + (hi - lo) * self.rng.gen_f32()).collect()
    }

    /// Vector of usizes `< bound` with a random length in `[min_len, max_len]`.
    pub fn usize_vec(&mut self, min_len: usize, max_len: usize, bound: usize) -> Vec<usize> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| self.rng.gen_range(bound)).collect()
    }

    /// Access the underlying PRNG for custom draws.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    /// Random dense regression dataset: `[min_rows, max_rows]` rows over
    /// `[1, max_features]` features, with a target mixing linear and
    /// nonlinear structure plus noise so grown trees have real splits.
    /// Used by the cross-engine parity properties.
    pub fn regression_dataset(
        &mut self,
        min_rows: usize,
        max_rows: usize,
        max_features: usize,
    ) -> Dataset {
        let n = self.usize_in(min_rows, max_rows);
        let d = self.usize_in(1, max_features);
        let features: Vec<Vec<f32>> = (0..d)
            .map(|_| (0..n).map(|_| self.f64_in(-2.0, 2.0) as f32).collect())
            .collect();
        let w: Vec<f64> = (0..d).map(|_| self.normal()).collect();
        let noise = self.f64_in(0.0, 0.3);
        let targets: Vec<f64> = (0..n)
            .map(|i| {
                let lin: f64 =
                    (0..d).map(|f| w[f] * features[f][i] as f64).sum();
                lin + (features[0][i] as f64 * 2.5).sin() + noise * self.normal()
            })
            .collect();
        Dataset {
            name: "prop-regression".into(),
            features,
            targets,
            labels: vec![],
            task: Task::Regression,
        }
    }
}

/// Run `cases` random cases of a property. The root seed is derived from
/// the property name so different properties explore different cases but
/// every run is deterministic. Panics (with the case seed) on the first
/// failing case.
pub fn run_prop(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut root: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        root ^= b as u64;
        root = root.wrapping_mul(0x100000001b3);
    }
    for case in 0..cases {
        let mut s = root.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let case_seed = crate::prng::splitmix64(&mut s);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(case_seed);
            prop(&mut g);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed at case {case} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by its reported seed.
pub fn replay_prop(case_seed: u64, mut prop: impl FnMut(&mut Gen)) {
    let mut g = Gen::new(case_seed);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("trivially true", 50, |g| {
            let _ = g.u64(10);
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_prop("always fails", 5, |_g| panic!("boom"));
        }));
        let msg = match caught {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("replay seed"), "got: {msg}");
        assert!(msg.contains("boom"), "got: {msg}");
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(99);
        let mut b = Gen::new(99);
        for _ in 0..20 {
            assert_eq!(a.u64(1_000_000), b.u64(1_000_000));
        }
    }

    #[test]
    fn usize_in_bounds() {
        let mut g = Gen::new(3);
        for _ in 0..1000 {
            let v = g.usize_in(5, 9);
            assert!((5..=9).contains(&v));
        }
    }
}
