//! Trees on a Diet: the paper's training-time contribution.
//!
//! * [`penalty`] — the reuse-penalty implementation of
//!   [`crate::gbdt::SplitPenalty`]: charging ι for first use of a
//!   feature and ξ for first use of a `(feature, threshold)` pair
//!   (paper Eq. 2/3).
//! * [`stats`] — reuse accounting: |F_U|, Σ|T^f|, distinct leaf values,
//!   and the reuse factor ReF reported in the sensitivity analyses
//!   (paper §4.3).
//! * [`train`] — ToaD training entry points, including
//!   `toad_forestsize`-style byte-budget-bounded training (§4.1).

pub mod penalty;
pub mod stats;
pub mod train;

pub use penalty::ToadPenalty;
pub use stats::ReuseStats;
pub use train::{train_toad, train_toad_with_budget, ToadParams, ToadModel};
