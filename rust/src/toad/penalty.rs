//! The ToaD reuse penalty (paper §3.1, Eq. 2/3, Appendix A).
//!
//! The modified regularizer `Ω_l(t_m) = Ω(t_m) + ι·|F_U| + ξ·Σ_f |T^f|`
//! charges the objective once for every *distinct* feature the ensemble
//! uses and once for every distinct threshold per feature. Folded into
//! the split gain this becomes `Δ_l = Δ − s_f·ι − s_t·ξ`, where `s_f`
//! (`s_t`) indicates that the candidate split would introduce a feature
//! (threshold) not yet used by any tree built so far — *including* the
//! tree currently being grown.
//!
//! Note that a split on a brand-new feature necessarily also introduces
//! a new threshold for it, so it is charged `ι + ξ`.

use crate::gbdt::splitter::SplitPenalty;
use std::collections::HashSet;

/// Penalty growth shape (paper §3.1, footnote 3).
///
/// * `Linear` — Eq. 2: every new feature costs ι, every new threshold
///   ξ (the regularizer the paper uses throughout its evaluation).
/// * `Escalating` — the footnote's alternative
///   `Ω_e = Ω + ι·Σ_{j=1}^{|F_U|} j + ξ·Σ_{j=1}^{p} j`: the *marginal*
///   cost of the (k+1)-th distinct feature is `ι·(k+1)` (and likewise
///   for thresholds), so each additional distinct value is charged
///   progressively more.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PenaltyShape {
    #[default]
    Linear,
    Escalating,
}

/// Reuse registries shared across all trees (and, for multiclass, all
/// per-class ensembles) of one training run.
#[derive(Clone, Debug)]
pub struct ToadPenalty {
    /// Feature penalty ι (`toad_penalty_feature`).
    pub iota: f64,
    /// Threshold penalty ξ (`toad_penalty_threshold`).
    pub xi: f64,
    /// Linear (paper default) or escalating (footnote 3) growth.
    pub shape: PenaltyShape,
    /// `F_U`: features used so far.
    used_features: Vec<bool>,
    n_used_features: usize,
    /// `T^f`: threshold boundary indices used so far, per feature.
    used_thresholds: Vec<HashSet<u16>>,
    n_used_thresholds: usize,
    /// Bumped whenever a registry grows (see `SplitPenalty::version`).
    version: u64,
}

impl ToadPenalty {
    pub fn new(n_features: usize, iota: f64, xi: f64) -> ToadPenalty {
        Self::with_shape(n_features, iota, xi, PenaltyShape::Linear)
    }

    /// Construct with an explicit penalty growth shape.
    pub fn with_shape(
        n_features: usize,
        iota: f64,
        xi: f64,
        shape: PenaltyShape,
    ) -> ToadPenalty {
        ToadPenalty {
            iota,
            xi,
            shape,
            used_features: vec![false; n_features],
            n_used_features: 0,
            used_thresholds: vec![HashSet::new(); n_features],
            n_used_thresholds: 0,
            version: 0,
        }
    }

    /// |F_U| — number of distinct features used.
    pub fn n_features_used(&self) -> usize {
        self.n_used_features
    }

    /// Σ_f |T^f| — total distinct thresholds across features.
    pub fn n_thresholds_used(&self) -> usize {
        self.n_used_thresholds
    }

    /// The set of used feature indices, sorted.
    pub fn features_used(&self) -> Vec<usize> {
        self.used_features
            .iter()
            .enumerate()
            .filter_map(|(f, &u)| u.then_some(f))
            .collect()
    }

    /// Sorted thresholds (boundary indices) used for feature `f`.
    pub fn thresholds_used(&self, f: usize) -> Vec<u16> {
        let mut v: Vec<u16> = self.used_thresholds[f].iter().copied().collect();
        v.sort_unstable();
        v
    }

    /// The regularizer value accumulated so far: `ι·|F_U| + ξ·Σ|T^f|`
    /// (linear) or the footnote's triangular sums (escalating).
    pub fn regularizer_value(&self) -> f64 {
        let (nf, nt) = (self.n_used_features as f64, self.n_used_thresholds as f64);
        match self.shape {
            PenaltyShape::Linear => self.iota * nf + self.xi * nt,
            PenaltyShape::Escalating => {
                self.iota * nf * (nf + 1.0) / 2.0 + self.xi * nt * (nt + 1.0) / 2.0
            }
        }
    }

    /// Marginal cost of introducing one more distinct feature.
    #[inline]
    fn feature_cost(&self) -> f64 {
        match self.shape {
            PenaltyShape::Linear => self.iota,
            PenaltyShape::Escalating => self.iota * (self.n_used_features + 1) as f64,
        }
    }

    /// Marginal cost of introducing one more distinct threshold.
    #[inline]
    fn threshold_cost(&self) -> f64 {
        match self.shape {
            PenaltyShape::Linear => self.xi,
            PenaltyShape::Escalating => self.xi * (self.n_used_thresholds + 1) as f64,
        }
    }
}

impl SplitPenalty for ToadPenalty {
    #[inline]
    fn penalty(&self, feature: usize, bin: u16) -> f64 {
        let s_f = !self.used_features[feature];
        // A new feature implies a new threshold for that feature.
        let s_t = s_f || !self.used_thresholds[feature].contains(&bin);
        (s_f as u8 as f64) * self.feature_cost() + (s_t as u8 as f64) * self.threshold_cost()
    }

    fn on_split(&mut self, feature: usize, bin: u16) {
        let mut grew = false;
        if !self.used_features[feature] {
            self.used_features[feature] = true;
            self.n_used_features += 1;
            grew = true;
        }
        if self.used_thresholds[feature].insert(bin) {
            self.n_used_thresholds += 1;
            grew = true;
        }
        if grew {
            self.version += 1;
        }
    }

    fn version(&self) -> u64 {
        self.version
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_feature_charges_both() {
        let p = ToadPenalty::new(4, 2.0, 0.5);
        assert_eq!(p.penalty(1, 7), 2.5);
    }

    #[test]
    fn reused_feature_new_threshold_charges_xi_only() {
        let mut p = ToadPenalty::new(4, 2.0, 0.5);
        p.on_split(1, 7);
        assert_eq!(p.penalty(1, 8), 0.5);
    }

    #[test]
    fn full_reuse_is_free() {
        let mut p = ToadPenalty::new(4, 2.0, 0.5);
        p.on_split(1, 7);
        assert_eq!(p.penalty(1, 7), 0.0);
    }

    #[test]
    fn version_bumps_only_on_growth() {
        let mut p = ToadPenalty::new(4, 1.0, 1.0);
        assert_eq!(p.version(), 0);
        p.on_split(0, 3);
        let v1 = p.version();
        assert!(v1 > 0);
        p.on_split(0, 3); // no growth
        assert_eq!(p.version(), v1);
        p.on_split(0, 4); // new threshold
        assert!(p.version() > v1);
    }

    #[test]
    fn counters_and_sets() {
        let mut p = ToadPenalty::new(8, 1.0, 1.0);
        p.on_split(2, 1);
        p.on_split(2, 5);
        p.on_split(6, 1);
        assert_eq!(p.n_features_used(), 2);
        assert_eq!(p.n_thresholds_used(), 3);
        assert_eq!(p.features_used(), vec![2, 6]);
        assert_eq!(p.thresholds_used(2), vec![1, 5]);
        assert_eq!(p.thresholds_used(6), vec![1]);
        assert_eq!(p.regularizer_value(), 2.0 + 3.0);
    }

    #[test]
    fn escalating_marginal_costs_grow() {
        let mut p = ToadPenalty::with_shape(8, 1.0, 0.5, PenaltyShape::Escalating);
        // First feature+threshold: 1·ι + 1·ξ.
        assert_eq!(p.penalty(0, 0), 1.0 + 0.5);
        p.on_split(0, 0);
        // Second feature: 2·ι; its threshold is the 2nd overall: 2·ξ.
        assert_eq!(p.penalty(1, 0), 2.0 + 1.0);
        // Reused feature, new threshold: only 2·ξ.
        assert_eq!(p.penalty(0, 1), 1.0);
        p.on_split(0, 1);
        // Third threshold now costs 3·ξ.
        assert_eq!(p.penalty(0, 2), 1.5);
    }

    #[test]
    fn escalating_regularizer_is_triangular() {
        let mut p = ToadPenalty::with_shape(8, 2.0, 1.0, PenaltyShape::Escalating);
        p.on_split(0, 0);
        p.on_split(1, 0);
        p.on_split(1, 1);
        // |F_U| = 2, p = 3: ι·(1+2) + ξ·(1+2+3) = 6 + 6.
        assert_eq!(p.regularizer_value(), 12.0);
    }

    #[test]
    fn linear_matches_paper_eq2() {
        let mut p = ToadPenalty::new(8, 2.0, 1.0);
        p.on_split(0, 0);
        p.on_split(1, 0);
        p.on_split(1, 1);
        assert_eq!(p.regularizer_value(), 2.0 * 2.0 + 1.0 * 3.0);
    }

    #[test]
    fn zero_penalties_are_neutral() {
        // ι = ξ = 0 must behave exactly like NoPenalty — this is the
        // "ToaD (layout only)" configuration of Figure 4.
        let p = ToadPenalty::new(4, 0.0, 0.0);
        assert_eq!(p.penalty(0, 0), 0.0);
        assert_eq!(p.penalty(3, 9), 0.0);
    }
}
