//! Reuse accounting over trained ensembles.
//!
//! The univariate sensitivity analysis (paper §4.3) tracks, per model:
//! the number of distinct features, the number of *global values*
//! (distinct thresholds + distinct leaf values), and the **reuse factor**
//!
//! ```text
//! ReF = (#internal nodes + #leaves) / #global values
//! ```
//!
//! `ReF = 1` means a naive one-value-per-node layout; `ReF = 2` means
//! every stored value is used twice on average.

use crate::gbdt::{GbdtModel, Tree};
use std::collections::HashSet;

/// Reuse statistics of a trained ensemble.
#[derive(Clone, Debug, PartialEq)]
pub struct ReuseStats {
    /// |F_U| — distinct features used by any split.
    pub n_features_used: usize,
    /// Σ_f |T^f| — distinct `(feature, threshold)` pairs.
    pub n_thresholds: usize,
    /// Distinct leaf values (bit-exact f32 comparison, as stored).
    pub n_leaf_values: usize,
    /// Total internal nodes across all trees.
    pub n_internal_nodes: usize,
    /// Total leaves across all trees.
    pub n_leaves: usize,
}

impl ReuseStats {
    /// Compute the statistics from a trained model.
    pub fn from_model(model: &GbdtModel) -> ReuseStats {
        let mut features: HashSet<usize> = HashSet::new();
        let mut thresholds: HashSet<(usize, u16)> = HashSet::new();
        let mut leaf_values: HashSet<u32> = HashSet::new();
        let mut n_internal = 0usize;
        let mut n_leaves = 0usize;
        for tree in model.trees.iter().flatten() {
            collect_tree(tree, &mut features, &mut thresholds, &mut leaf_values);
            n_internal += tree.n_internal();
            n_leaves += tree.n_leaves();
        }
        ReuseStats {
            n_features_used: features.len(),
            n_thresholds: thresholds.len(),
            n_leaf_values: leaf_values.len(),
            n_internal_nodes: n_internal,
            n_leaves,
        }
    }

    /// Number of global values (thresholds + leaf values) — the
    /// denominator of ReF and the y-axis of Figure 6 (bottom).
    pub fn n_global_values(&self) -> usize {
        self.n_thresholds + self.n_leaf_values
    }

    /// The reuse factor ReF (paper §4.3).
    pub fn reuse_factor(&self) -> f64 {
        let refs = self.n_internal_nodes + self.n_leaves;
        let values = self.n_global_values();
        if values == 0 {
            1.0
        } else {
            refs as f64 / values as f64
        }
    }
}

fn collect_tree(
    tree: &Tree,
    features: &mut HashSet<usize>,
    thresholds: &mut HashSet<(usize, u16)>,
    leaf_values: &mut HashSet<u32>,
) {
    for (f, b, _) in tree.splits() {
        features.insert(f);
        thresholds.insert((f, b));
    }
    for v in tree.leaf_values() {
        leaf_values.insert((v as f32).to_bits());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gbdt::loss::Objective;
    use crate::gbdt::tree::Node;

    fn model_with_reuse() -> GbdtModel {
        // Two trees sharing feature 0 / bin 3 and one leaf value.
        let t1 = Tree {
            nodes: vec![
                Node::Internal { feature: 0, bin: 3, threshold: 0.5, left: 1, right: 2 },
                Node::Leaf { value: 1.0 },
                Node::Leaf { value: 2.0 },
            ],
        };
        let t2 = Tree {
            nodes: vec![
                Node::Internal { feature: 0, bin: 3, threshold: 0.5, left: 1, right: 2 },
                Node::Leaf { value: 1.0 },
                Node::Leaf { value: 3.0 },
            ],
        };
        GbdtModel {
            objective: Objective::L2,
            base_scores: vec![0.0],
            trees: vec![vec![t1, t2]],
            n_features: 2,
            name: "m".into(),
        }
    }

    #[test]
    fn counts_distinct_values() {
        let s = ReuseStats::from_model(&model_with_reuse());
        assert_eq!(s.n_features_used, 1);
        assert_eq!(s.n_thresholds, 1);
        assert_eq!(s.n_leaf_values, 3); // {1.0, 2.0, 3.0}
        assert_eq!(s.n_internal_nodes, 2);
        assert_eq!(s.n_leaves, 4);
        assert_eq!(s.n_global_values(), 4);
        // ReF = (2 + 4) / (1 + 3) = 1.5
        assert!((s.reuse_factor() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn no_reuse_gives_ref_one() {
        // A single stump: 1 threshold + 2 leaf values = 3 values, 3 refs.
        let t = Tree {
            nodes: vec![
                Node::Internal { feature: 1, bin: 0, threshold: 0.1, left: 1, right: 2 },
                Node::Leaf { value: -1.0 },
                Node::Leaf { value: 1.0 },
            ],
        };
        let m = GbdtModel {
            objective: Objective::L2,
            base_scores: vec![0.0],
            trees: vec![vec![t]],
            n_features: 2,
            name: "m".into(),
        };
        let s = ReuseStats::from_model(&m);
        assert!((s.reuse_factor() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bare_leaf_model() {
        let m = GbdtModel {
            objective: Objective::L2,
            base_scores: vec![0.0],
            trees: vec![vec![Tree::leaf(0.5)]],
            n_features: 2,
            name: "m".into(),
        };
        let s = ReuseStats::from_model(&m);
        assert_eq!(s.n_features_used, 0);
        assert_eq!(s.n_global_values(), 1);
        assert_eq!(s.n_leaves, 1);
    }
}
