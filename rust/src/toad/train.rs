//! ToaD training entry points.
//!
//! [`train_toad`] runs a penalized boosting run and packages the model
//! with its reuse statistics and encoded size. [`train_toad_with_budget`]
//! implements the `toad_forestsize` option (paper §4.1): boosting
//! continues only while the *encoded* model fits the byte budget, so a
//! model can be trained directly for, say, a 32 KB Arduino.

use super::penalty::ToadPenalty;
use super::stats::ReuseStats;
use crate::data::Dataset;
use crate::gbdt::booster::{Booster, GbdtParams};
use crate::gbdt::GbdtModel;
use crate::layout::{encode, toad_format::size_breakdown, EncodeOptions, FeatureInfo};

/// Hyperparameters of a ToaD run: the underlying booster's parameters
/// plus the two paper knobs and an optional byte budget.
#[derive(Clone, Copy, Debug)]
pub struct ToadParams {
    pub gbdt: GbdtParams,
    /// Feature penalty ι (`toad_penalty_feature`).
    pub iota: f64,
    /// Threshold penalty ξ (`toad_penalty_threshold`).
    pub xi: f64,
    /// Linear (paper Eq. 2, the default) or escalating (footnote 3).
    pub shape: super::penalty::PenaltyShape,
    /// Optional `toad_forestsize`: stop boosting before the encoded
    /// model would exceed this many bytes.
    pub forestsize_bytes: Option<usize>,
    pub encode: EncodeOptions,
}

impl ToadParams {
    pub fn new(gbdt: GbdtParams, iota: f64, xi: f64) -> ToadParams {
        ToadParams {
            gbdt,
            iota,
            xi,
            shape: super::penalty::PenaltyShape::Linear,
            forestsize_bytes: None,
            encode: EncodeOptions::default(),
        }
    }
}

/// A trained ToaD model: the ensemble, its packed encoding, and the
/// reuse bookkeeping the paper's analyses report.
#[derive(Clone, Debug)]
pub struct ToadModel {
    pub model: GbdtModel,
    pub stats: ReuseStats,
    /// Encoded blob in the ToaD layout.
    pub blob: Vec<u8>,
    /// |F_U| and Σ|T^f| as tracked by the training-time registries
    /// (equal to `stats` counts; kept for cross-checking).
    pub registry_features: usize,
    pub registry_thresholds: usize,
}

impl ToadModel {
    pub fn size_bytes(&self) -> usize {
        self.blob.len()
    }

    pub fn reuse_factor(&self) -> f64 {
        self.stats.reuse_factor()
    }
}

/// Train with the reuse penalties (no byte budget).
pub fn train_toad(data: &Dataset, params: &ToadParams) -> ToadModel {
    let penalty =
        ToadPenalty::with_shape(data.n_features(), params.iota, params.xi, params.shape);
    let mut booster = Booster::new(data, params.gbdt, penalty);
    booster.run();
    finalize(data, params, booster)
}

/// Train under a byte budget: after each boosting round the model is
/// size-checked in the ToaD layout; the run stops at the last round that
/// still fits (the overshooting round is rolled back).
pub fn train_toad_with_budget(data: &Dataset, params: &ToadParams) -> ToadModel {
    let budget = params.forestsize_bytes.expect("budget training requires forestsize_bytes");
    let finfo = FeatureInfo::from_dataset(data);
    let penalty =
        ToadPenalty::with_shape(data.n_features(), params.iota, params.xi, params.shape);
    let mut booster = Booster::new(data, params.gbdt, penalty);

    // Snapshot of the last model that fit the budget.
    let mut last_fit: Option<GbdtModel> = None;
    while booster.rounds_done() < params.gbdt.n_rounds {
        let any_split = booster.boost_round();
        let bd = size_breakdown(booster.model(), &finfo, &params.encode);
        if bd.total_bytes() <= budget {
            last_fit = Some(booster.model().clone());
        } else {
            break;
        }
        if !any_split {
            break; // further rounds would add identical bare leaves
        }
    }
    let model = last_fit.unwrap_or_else(|| {
        // Even one round overshoots: fall back to the base-score-only
        // model (no trees), the smallest expressible ensemble.
        let mut m = booster.model().clone();
        for trees in &mut m.trees {
            trees.clear();
        }
        m
    });
    package(data, params, model, booster.penalty().clone())
}

fn finalize(data: &Dataset, params: &ToadParams, booster: Booster<ToadPenalty>) -> ToadModel {
    let penalty = booster.penalty().clone();
    let model = booster.into_model();
    package(data, params, model, penalty)
}

fn package(
    data: &Dataset,
    params: &ToadParams,
    model: GbdtModel,
    penalty: ToadPenalty,
) -> ToadModel {
    let finfo = FeatureInfo::from_dataset(data);
    // Training is bounded by `params.gbdt.max_depth`, so a width
    // overflow here means the caller configured an un-encodable model —
    // surface the encoder's message rather than a corrupt blob.
    let blob = encode(&model, &finfo, &params.encode)
        .expect("trained model exceeds a ToaD layout header field");
    let stats = ReuseStats::from_model(&model);
    ToadModel {
        stats,
        blob,
        registry_features: penalty.n_features_used(),
        registry_thresholds: penalty.n_thresholds_used(),
        model,
    }
}

#[cfg(test)]
#[cfg(not(miri))] // trains models / generates datasets - too slow under the Miri interpreter
mod tests {
    use super::*;
    use crate::data::synth::PaperDataset;
    use crate::data::train_test_split;

    fn small(ds: PaperDataset, n: usize, seed: u64) -> (Dataset, Dataset) {
        let full = ds.generate(seed);
        let data = full.select(&(0..n.min(full.n_rows())).collect::<Vec<_>>());
        train_test_split(&data, 0.2, seed)
    }

    #[test]
    fn zero_penalties_reproduce_plain_training_exactly() {
        // The "ToaD (layout only)" series of Figure 4 assumes ι=ξ=0
        // training is *identical* to plain LightGBM-style training —
        // same trees, same predictions (the penalty hook must be
        // perfectly neutral, including its lazy-revalidation path).
        for ds in [PaperDataset::BreastCancer, PaperDataset::Kin8nm] {
            let (train_set, test_set) = small(ds, 500, 9);
            let gbdt = GbdtParams::paper(12, 3);
            let toad = train_toad(&train_set, &ToadParams::new(gbdt, 0.0, 0.0));
            let plain = crate::gbdt::booster::train(&train_set, gbdt);
            assert_eq!(toad.model.n_trees(), plain.n_trees());
            for i in (0..test_set.n_rows()).step_by(13) {
                let x = test_set.row(i);
                assert_eq!(
                    toad.model.predict_raw(&x),
                    plain.predict_raw(&x),
                    "{}: row {i} diverged",
                    ds.name()
                );
            }
        }
    }

    #[test]
    fn escalating_shape_is_more_aggressive_at_small_penalty() {
        // With tiny per-unit penalties the escalating shape's growing
        // marginal costs must not use *more* distinct thresholds than
        // the linear shape at the same (ι, ξ).
        let (train_set, _) = small(PaperDataset::CovertypeBinary, 3000, 10);
        let gbdt = GbdtParams::paper(32, 2);
        let lin = train_toad(&train_set, &ToadParams::new(gbdt, 0.1, 0.05));
        let mut esc_params = ToadParams::new(gbdt, 0.1, 0.05);
        esc_params.shape = crate::toad::penalty::PenaltyShape::Escalating;
        let esc = train_toad(&train_set, &esc_params);
        assert!(
            esc.stats.n_thresholds <= lin.stats.n_thresholds,
            "escalating {} > linear {}",
            esc.stats.n_thresholds,
            lin.stats.n_thresholds
        );
    }

    #[test]
    fn registries_match_model_stats() {
        let (train_set, _) = small(PaperDataset::BreastCancer, 569, 1);
        let params = ToadParams::new(GbdtParams::paper(16, 2), 0.5, 0.1);
        let m = train_toad(&train_set, &params);
        assert_eq!(m.registry_features, m.stats.n_features_used);
        assert_eq!(m.registry_thresholds, m.stats.n_thresholds);
    }

    #[test]
    fn higher_feature_penalty_uses_fewer_features() {
        let (train_set, _) = small(PaperDataset::BreastCancer, 569, 2);
        let gbdt = GbdtParams::paper(32, 2);
        let lo = train_toad(&train_set, &ToadParams::new(gbdt, 0.0, 0.0));
        let hi = train_toad(&train_set, &ToadParams::new(gbdt, 50.0, 0.0));
        assert!(
            hi.stats.n_features_used <= lo.stats.n_features_used,
            "ι should not increase features: {} vs {}",
            hi.stats.n_features_used,
            lo.stats.n_features_used
        );
        assert!(hi.stats.n_features_used >= 1 || hi.model.n_trees() == 0);
    }

    #[test]
    fn higher_threshold_penalty_uses_fewer_thresholds() {
        let (train_set, _) = small(PaperDataset::CaliforniaHousing, 3000, 3);
        let gbdt = GbdtParams::paper(32, 2);
        let lo = train_toad(&train_set, &ToadParams::new(gbdt, 0.0, 0.0));
        let hi = train_toad(&train_set, &ToadParams::new(gbdt, 0.0, 100.0));
        assert!(
            hi.stats.n_thresholds < lo.stats.n_thresholds,
            "ξ should reduce thresholds: {} vs {}",
            hi.stats.n_thresholds,
            lo.stats.n_thresholds
        );
    }

    #[test]
    fn penalties_shrink_encoded_size_at_similar_rounds() {
        let (train_set, _) = small(PaperDataset::Mushroom, 3000, 4);
        let gbdt = GbdtParams::paper(32, 3);
        let plain = train_toad(&train_set, &ToadParams::new(gbdt, 0.0, 0.0));
        let pen = train_toad(&train_set, &ToadParams::new(gbdt, 8.0, 4.0));
        assert!(
            pen.size_bytes() <= plain.size_bytes(),
            "penalized {} > plain {}",
            pen.size_bytes(),
            plain.size_bytes()
        );
    }

    #[test]
    fn budget_training_respects_limit() {
        let (train_set, _) = small(PaperDataset::BreastCancer, 569, 5);
        for budget in [256usize, 512, 1024, 4096] {
            let mut params = ToadParams::new(GbdtParams::paper(64, 2), 1.0, 0.5);
            params.forestsize_bytes = Some(budget);
            let m = train_toad_with_budget(&train_set, &params);
            assert!(
                m.size_bytes() <= budget,
                "model {} bytes exceeds budget {budget}",
                m.size_bytes()
            );
        }
    }

    #[test]
    fn budget_training_uses_budget() {
        // A generous budget must produce a bigger (better-fitting) model
        // than a tiny one.
        let (train_set, test_set) = small(PaperDataset::BreastCancer, 569, 6);
        let mk = |budget| {
            let mut params = ToadParams::new(GbdtParams::paper(64, 2), 0.5, 0.25);
            params.forestsize_bytes = Some(budget);
            train_toad_with_budget(&train_set, &params)
        };
        let tiny = mk(200);
        let big = mk(8192);
        assert!(big.size_bytes() > tiny.size_bytes());
        assert!(big.model.score(&test_set) >= tiny.model.score(&test_set) - 0.02);
    }

    #[test]
    fn oblivious_growth_encodes_the_compact_body_end_to_end() {
        // ToaD penalties and oblivious growth compose: the booster's
        // `growth` knob reaches the grower through `ToadParams::gbdt`,
        // every grown tree is level-uniform, and the encoder stores the
        // compact oblivious body (d pairs + 2^d leaves) for each —
        // which the packed reader serves back bit-exactly.
        let (train_set, test_set) = small(PaperDataset::BreastCancer, 500, 12);
        let mut gbdt = GbdtParams::paper(16, 3);
        gbdt.growth = crate::gbdt::GrowthMode::Oblivious;
        let m = train_toad(&train_set, &ToadParams::new(gbdt, 0.5, 0.25));
        let grew = m.model.trees.iter().flatten().filter(|t| t.depth() > 0).count();
        assert!(grew > 0, "oblivious ToaD must grow at least one real tree");
        for tree in m.model.trees.iter().flatten().filter(|t| t.depth() > 0) {
            assert!(tree.oblivious_levels().is_some());
        }
        let packed = crate::layout::PackedModel::from_bytes(m.blob.clone());
        assert_eq!(packed.n_oblivious_trees(), grew, "every grown tree stores the compact body");
        // The packed reader and the decoded pointer model agree
        // bit-exactly (leaf quantization applies to both equally).
        let decoded = crate::layout::decode(&m.blob);
        for i in (0..test_set.n_rows()).step_by(19) {
            let x = test_set.row(i);
            assert_eq!(packed.predict_raw(&x), decoded.predict_raw(&x), "row {i}");
        }
    }

    #[test]
    fn reuse_factor_at_least_one_for_nonempty() {
        let (train_set, _) = small(PaperDataset::KrVsKp, 1500, 7);
        let params = ToadParams::new(GbdtParams::paper(16, 2), 0.0, 2.0);
        let m = train_toad(&train_set, &params);
        assert!(m.reuse_factor() >= 1.0 - 1e-9, "ReF {}", m.reuse_factor());
    }
}
