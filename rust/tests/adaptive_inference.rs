//! Adaptive early-exit inference: safety and parity guarantees of the
//! margin-bounded descent kernel (`AdaptivePolicy::Margin`) against the
//! exact engines.
//!
//! * **Unarmed ≡ exact, bit for bit.** `Margin(0.0)` (and any
//!   non-positive or NaN tolerance) must route through the exact kernel
//!   and reproduce full descent exactly — same bits, full
//!   `trees_evaluated` — on every SIMD tier, NaN rows and ragged block
//!   tails included.
//! * **Sign-decided exits never flip the class.** The suffix bounds are
//!   true extrema of the remaining raw-score mass, so a row released
//!   because its partial score ± the remaining bound cannot cross zero
//!   must agree with full descent on the predicted class. Width exits
//!   carry an error under `eps/2`, so they can flip only rows whose
//!   full |raw| is inside the tolerance band.
//! * **Lane compaction preserves row order.** The block kernel
//!   swap-removes exited lanes mid-descent; outputs must still land on
//!   their original rows — pinned by comparing whole batches against
//!   per-row singleton calls at every block-boundary size, across
//!   tiers, and between the row-major and columnar entry points.
//! * **Easy-majority workloads save real work.** On a near-separable
//!   task a tiny tolerance must strictly reduce mean trees evaluated
//!   with zero class flips — the tentpole claim of the adaptive engine.

// Everything below trains real models, spawns threads, or sweeps large
// inputs - orders of magnitude too slow under the Miri interpreter.
// `tests/miri_surface.rs` holds the fast coverage that stays in Miri runs.
#![cfg(not(miri))]

use toad::data::synth::PaperDataset;
use toad::gbdt::{booster, GbdtParams};
use toad::inference::{AdaptivePolicy, Predictor, QuantizedFlatModel};
use toad::simd::{self, Tier};
use toad::testutil::prop::run_prop;

/// Transpose rows into the columnar layout the zero-gather path eats.
fn columns(rows: &[Vec<f32>], nf: usize) -> Vec<Vec<f32>> {
    (0..nf).map(|f| rows.iter().map(|r| r[f]).collect()).collect()
}

#[test]
fn prop_unarmed_policies_match_full_descent_bit_for_bit() {
    run_prop("unarmed adaptive == exact descent", 10, |g| {
        let data = g.regression_dataset(40, 200, 6);
        let rounds = g.usize_in(2, 8);
        let depth = g.usize_in(1, 5);
        let model = booster::train(&data, GbdtParams::paper(rounds, depth));
        let quant = QuantizedFlatModel::from_model(&model);
        let n_trees = Predictor::n_trees(&quant) as u32;
        // Ragged sizes around the lane groups, NaN injected.
        let n_rows = if g.bool(0.5) { g.usize_in(1, 17) } else { g.usize_in(18, 80) };
        let rows: Vec<Vec<f32>> = (0..n_rows)
            .map(|i| {
                let mut r = data.row(i % data.n_rows());
                if g.bool(0.3) {
                    let f = g.usize(r.len());
                    r[f] = f32::NAN;
                }
                r
            })
            .collect();
        let want = quant.predict_batch_with_tier(&rows, Tier::Scalar);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(want[i], model.predict_raw(row), "scalar batch vs pointer, row {i}");
        }
        let unarmed = [
            AdaptivePolicy::Exact,
            AdaptivePolicy::Margin(0.0),
            AdaptivePolicy::Margin(-1.0),
            AdaptivePolicy::Margin(f32::NAN),
        ];
        for tier in simd::available_tiers().into_iter().chain([Tier::Avx2]) {
            for policy in unarmed {
                let ab = quant.predict_batch_adaptive_with_tier(&rows, policy, tier);
                assert_eq!(
                    ab.scores,
                    want,
                    "unarmed {policy:?} diverged from full descent on tier {}",
                    tier.name()
                );
                assert!(
                    ab.trees_evaluated.iter().all(|&t| t == n_trees),
                    "unarmed {policy:?} must report full depth"
                );
            }
        }
    });
}

#[test]
fn prop_sign_exits_never_flip_the_class() {
    run_prop("margin exits preserve predicted class", 8, |g| {
        let seed = g.u64(1_000) + 1;
        let n = g.usize_in(120, 360);
        let data = PaperDataset::BreastCancer
            .generate(seed)
            .select(&(0..n).collect::<Vec<_>>());
        let rounds = g.usize_in(4, 24);
        let model = booster::train(&data, GbdtParams::paper(rounds, 2));
        let quant = model.quantize();
        let n_trees = Predictor::n_trees(&quant) as u32;
        let rows: Vec<Vec<f32>> = (0..data.n_rows()).map(|i| data.row(i)).collect();
        let full = quant.predict_batch(&rows);
        let eps = [1e-12f32, 1e-3, 0.5][g.usize(3)];
        let ab = quant.predict_batch_adaptive(&rows, AdaptivePolicy::Margin(eps));
        for i in 0..rows.len() {
            let t = ab.trees_evaluated[i];
            assert!((1..=n_trees).contains(&t), "row {i}: trees_evaluated {t} out of range");
            if t == n_trees {
                // Rows that ran to completion are bit-identical: the
                // compaction never reorders the summation of survivors.
                assert_eq!(ab.scores[i], full[i], "row {i}: non-exited row diverged");
            }
            // Sign-decided exits agree with full descent by
            // construction; width exits err below eps/2, so a class
            // flip is only possible inside the tolerance band.
            let flipped = (ab.scores[i][0] > 0.0) != (full[i][0] > 0.0);
            assert!(
                !flipped || full[i][0].abs() < f64::from(eps),
                "row {i}: flip outside the eps band (full {}, adaptive {}, eps {eps})",
                full[i][0],
                ab.scores[i][0]
            );
        }
    });
}

#[test]
fn prop_margin_bounds_regression_error() {
    run_prop("L2 width exits stay within eps/2", 8, |g| {
        let data = g.regression_dataset(60, 220, 5);
        let rounds = g.usize_in(2, 10);
        let model = booster::train(&data, GbdtParams::paper(rounds, 3));
        let quant = QuantizedFlatModel::from_model(&model);
        let rows: Vec<Vec<f32>> = (0..data.n_rows()).map(|i| data.row(i)).collect();
        let full = quant.predict_batch(&rows);
        let eps = g.f64_in(0.05, 4.0) as f32;
        // L2 has no sign semantics, so only width exits arm: every
        // released row's midpoint is within half the remaining band.
        let ab = quant.predict_batch_adaptive(&rows, AdaptivePolicy::Margin(eps));
        for i in 0..rows.len() {
            let err = (ab.scores[i][0] - full[i][0]).abs();
            assert!(
                err <= 0.5 * f64::from(eps) + 1e-9,
                "row {i}: width-exit error {err} exceeds eps/2 = {}",
                0.5 * f64::from(eps)
            );
        }
    });
}

#[test]
fn lane_compaction_preserves_row_order_at_block_boundaries() {
    // Near-separable task + small tolerance: most lanes exit early, so
    // the swap-to-back compaction is genuinely exercised, and every
    // output must still land on its original row. Singleton calls are
    // the oracle — a row's exit depends only on its own partial sum, so
    // batching must not change either score or depth.
    let data = PaperDataset::Mushroom.generate(91).select(&(0..300).collect::<Vec<_>>());
    let model = booster::train(&data, GbdtParams::paper(16, 2));
    let quant = model.quantize();
    let n_trees = Predictor::n_trees(&quant) as f64;
    let policy = AdaptivePolicy::Margin(0.5);
    let all_rows: Vec<Vec<f32>> = (0..135)
        .map(|i| {
            let mut r = data.row(i % data.n_rows());
            if i % 11 == 0 {
                r[i % r.len()] = f32::NAN;
            }
            r
        })
        .collect();
    let nf = data.n_features();

    // Per-row singleton oracle (computed once on the dispatched tier;
    // every tier must agree below).
    let oracle: Vec<(Vec<f64>, u32)> = all_rows
        .iter()
        .map(|r| {
            let one = quant.predict_batch_adaptive(std::slice::from_ref(r), policy);
            (one.scores[0].clone(), one.trees_evaluated[0])
        })
        .collect();

    for n in [1usize, 63, 64, 65, 128, 135] {
        let rows = &all_rows[..n];
        let cols = columns(rows, nf);
        let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        for tier in simd::available_tiers().into_iter().chain([Tier::Avx2]) {
            let ab = quant.predict_batch_adaptive_with_tier(rows, policy, tier);
            assert_eq!(ab.scores.len(), n);
            for i in 0..n {
                assert_eq!(
                    (ab.scores[i].clone(), ab.trees_evaluated[i]),
                    oracle[i],
                    "n={n} row {i} tier {}: batched adaptive diverged from singleton",
                    tier.name()
                );
            }
            let cb = quant.predict_batch_columns_adaptive_with_tier(&col_refs, n, policy, tier);
            assert_eq!(cb.scores, ab.scores, "n={n} tier {}: columnar scores", tier.name());
            assert_eq!(
                cb.trees_evaluated,
                ab.trees_evaluated,
                "n={n} tier {}: columnar depths",
                tier.name()
            );
        }
    }
    // The compaction must actually have fired: a separable task at this
    // tolerance cannot be running every row to full depth.
    let mean = quant.predict_batch_adaptive(&all_rows, policy).mean_trees();
    assert!(mean < n_trees, "no early exits — the compaction path went unexercised");
}

#[test]
fn easy_majority_margin_saves_work_with_zero_flips() {
    let data = PaperDataset::Mushroom.generate(93).select(&(0..600).collect::<Vec<_>>());
    let model = booster::train(&data, GbdtParams::paper(32, 2));
    let quant = model.quantize();
    let n_trees = Predictor::n_trees(&quant);
    let rows: Vec<Vec<f32>> = (0..data.n_rows()).map(|i| data.row(i)).collect();
    let full = quant.predict_batch(&rows);
    let eps = 1e-6f32;
    let ab = quant.predict_batch_adaptive(&rows, AdaptivePolicy::Margin(eps));
    let mut flips = 0usize;
    for i in 0..rows.len() {
        if (ab.scores[i][0] > 0.0) != (full[i][0] > 0.0) && full[i][0].abs() >= f64::from(eps) {
            flips += 1;
        }
    }
    assert_eq!(flips, 0, "margin exits flipped classes outside the eps band");
    assert!(
        ab.mean_trees() < n_trees as f64,
        "separable majority task must evaluate strictly fewer mean trees ({} vs {n_trees})",
        ab.mean_trees()
    );
}
