//! Integration: full coordinator stack — sweep candidates → planner →
//! devices + gateway batchers → served predictions.

// Everything below trains real models, spawns threads, or sweeps large
// inputs - orders of magnitude too slow under the Miri interpreter.
// `tests/miri_surface.rs` holds the fast coverage that stays in Miri runs.
#![cfg(not(miri))]

use std::time::Duration;
use toad::coordinator::batcher::{Backend, Batcher, BatcherConfig};
use toad::coordinator::{DeploymentPlanner, DeviceKind, FleetServer, ModelCard, SimulatedDevice};
use toad::data::synth::PaperDataset;
use toad::data::train_test_split;
use toad::gbdt::GbdtParams;
use toad::toad::{train_toad, ToadParams};

#[test]
fn plan_deploy_and_serve_on_device() {
    let data = PaperDataset::BreastCancer.generate(5);
    let (train_set, test_set) = train_test_split(&data, 0.2, 5);

    // Sweep a few configurations into a candidate pool.
    let mut planner = DeploymentPlanner::new();
    for (rounds, iota, xi) in [(4usize, 0.0, 0.0), (16, 1.0, 0.5), (64, 2.0, 1.0)] {
        let params = ToadParams::new(GbdtParams::paper(rounds, 2), iota, xi);
        let m = train_toad(&train_set, &params);
        planner.add_candidate(ModelCard {
            id: format!("bc_r{rounds}_i{iota}_x{xi}"),
            score: m.model.score(&test_set),
            size_bytes: m.size_bytes(),
            blob: m.blob.clone(),
        });
    }

    // Deploy the best fit onto a tiny node and serve.
    let mut device = SimulatedDevice::new(0, DeviceKind::TinyNode); // 1 KB
    let chosen = planner.deploy_to(&mut device).unwrap();
    assert!(device.model_size().unwrap() <= 1024, "chosen {chosen} too big");

    let mut server = FleetServer::new();
    server.add_device("bc", device);
    let mut correct = 0usize;
    let n = test_set.n_rows();
    for i in 0..n {
        let out = server.predict("bc", test_set.row(i)).unwrap();
        let pred = (out[0] > 0.0) as usize;
        if pred == test_set.labels[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.85, "served accuracy {acc} too low for a 1 KB model");
    let m = server.metrics("bc").unwrap();
    assert_eq!(m.count(), n);
    assert!(server.fleet_sim_busy_seconds() > 0.0, "device time accounted");
}

/// The dependency-free batched serving path: a gateway backed by the
/// flattened native engine must agree with the source model exactly.
#[test]
fn native_gateway_serves_batches() {
    let data = PaperDataset::CovertypeBinary.generate(6);
    let data = data.select(&(0..4000).collect::<Vec<_>>());
    let (train_set, test_set) = train_test_split(&data, 0.2, 6);
    let params = ToadParams::new(GbdtParams::paper(32, 3), 1.0, 0.5);
    let m = train_toad(&train_set, &params);

    let batcher = Batcher::spawn(
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            ..Default::default()
        },
        Backend::Native(m.model.flatten()),
    );
    let mut server = FleetServer::new();
    server.add_gateway("cov", batcher);

    let n = 200usize;
    let mut correct = 0usize;
    for i in 0..n {
        let out = server.predict("cov", test_set.row(i)).unwrap();
        let pred = (out[0] > 0.0) as usize;
        let want = m.model.predict_class(&test_set.row(i));
        assert_eq!(pred, want, "gateway disagrees with source model at row {i}");
        if pred == test_set.labels[i] {
            correct += 1;
        }
    }
    assert!(correct as f64 / n as f64 > 0.6);
    let rec = server.metrics("cov").unwrap();
    assert_eq!(rec.count(), n);
}

#[cfg(feature = "xla")]
mod xla_gateway {
    use super::*;
    use toad::runtime::tensorize;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("MANIFEST.txt").exists() {
            Some(dir)
        } else {
            eprintln!("skipping xla-gateway test: no artifacts (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn xla_gateway_serves_batches() {
        let Some(dir) = artifacts_dir() else { return };
        let data = PaperDataset::CovertypeBinary.generate(6);
        let data = data.select(&(0..4000).collect::<Vec<_>>());
        let (train_set, test_set) = train_test_split(&data, 0.2, 6);
        let params = ToadParams::new(GbdtParams::paper(32, 3), 1.0, 0.5);
        let m = train_toad(&train_set, &params);
        let tm = tensorize(&m.model, 256, 4, 64, 1).unwrap();

        let batcher = Batcher::spawn(
            BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(2),
                queue_depth: 1024,
                ..Default::default()
            },
            Backend::Xla { artifacts_dir: dir, features: 64, tensors: tm },
        );
        let mut server = FleetServer::new();
        server.add_gateway("cov", batcher);

        let n = 200usize;
        let mut correct = 0usize;
        for i in 0..n {
            let out = server.predict("cov", test_set.row(i)).unwrap();
            let pred = (out[0] > 0.0) as usize;
            let want = m.model.predict_class(&test_set.row(i));
            assert_eq!(pred, want, "gateway disagrees with source model at row {i}");
            if pred == test_set.labels[i] {
                correct += 1;
            }
        }
        assert!(correct as f64 / n as f64 > 0.6);
        let rec = server.metrics("cov").unwrap();
        assert_eq!(rec.count(), n);
    }
}
