//! Decode-robustness corpus: `try_decode` on hostile bytes.
//!
//! The ToaD blob is the crate's untrusted input surface — a model read
//! back from device flash or over a wire arrives as raw bytes, and the
//! documented contract is that [`toad_format::try_decode`] *returns*
//! `Err` on anything malformed and never panics. This file pins that
//! contract with a deterministic corpus:
//!
//! * every strict prefix of a real encoded blob (truncation),
//! * every single-bit flip of a real encoded blob (corruption),
//! * hand-packed headers exercising each `validate_blob` rejection
//!   path, including the PR 2 width-overflow family (fields at or past
//!   their fixed header widths) and the out-of-range reference family
//!   (feature/threshold/leaf indices past their tables) that the
//!   original size-only validator let through to a panicking `decode`.
//!
//! The two sweeps over the *trained* blob are tagged out of Miri (the
//! blob is a few KB, so the sweep is quadratic in its size); the
//! hand-packed corpus is small and stays in Miri runs, where it doubles
//! as coverage of `BitReader`'s unaligned read paths.

use std::panic::{catch_unwind, AssertUnwindSafe};

use toad::bitio::BitWriter;
use toad::data::synth::PaperDataset;
use toad::gbdt::{self, GbdtParams};
use toad::layout::toad_format::{try_decode, validate_blob};
use toad::layout::{encode, EncodeOptions, FeatureInfo};

/// A small real artifact: trained, encoded, and known-good.
fn trained_blob() -> Vec<u8> {
    let data = PaperDataset::BreastCancer.generate(7).select(&(0..120).collect::<Vec<_>>());
    let model = gbdt::booster::train(&data, GbdtParams::paper(2, 2));
    let finfo = FeatureInfo::from_dataset(&data);
    encode(&model, &finfo, &EncodeOptions::default()).unwrap()
}

/// `try_decode` must return (Ok *or* Err) — panicking is the failure.
fn decodes_without_panic(bytes: &[u8], what: &str) -> bool {
    match catch_unwind(AssertUnwindSafe(|| try_decode(bytes))) {
        Ok(result) => result.is_ok(),
        Err(_) => panic!("try_decode panicked on {what}"),
    }
}

// ---------------------------------------------------------------------
// Sweeps over a real encoded model.
// ---------------------------------------------------------------------

#[test]
#[cfg_attr(miri, ignore)] // trains a model and sweeps a KB-scale blob
fn every_strict_prefix_of_a_real_blob_is_rejected() {
    let blob = trained_blob();
    assert!(try_decode(&blob).is_ok(), "the untruncated blob must decode");
    for k in 0..blob.len() {
        assert!(
            !decodes_without_panic(&blob[..k], &format!("prefix of {k} bytes")),
            "a {k}-byte prefix of a {}-byte blob validated as complete",
            blob.len()
        );
    }
}

#[test]
#[cfg_attr(miri, ignore)] // trains a model and sweeps every bit of the blob
fn every_single_bit_flip_of_a_real_blob_is_handled() {
    let blob = trained_blob();
    let mut flipped = blob.clone();
    for byte in 0..blob.len() {
        for bit in 0..8 {
            flipped[byte] ^= 1 << bit;
            // Ok (benign flip, e.g. inside a leaf f32) and Err are both
            // acceptable; the assertion is that neither path panics.
            decodes_without_panic(&flipped, &format!("bit flip at byte {byte} bit {bit}"));
            flipped[byte] ^= 1 << bit;
        }
    }
    assert_eq!(flipped, blob, "sweep must restore the blob");
}

// ---------------------------------------------------------------------
// Hand-packed corpus. Field widths mirror the format header exactly:
// task(2) outputs(8) rounds(16) depth(4) d(16) |F_U|(16) maxT(16)
// leafvals(24), then one f32 base score per output.
// ---------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn header(
    task: u64,
    n_outputs: u64,
    n_rounds: u64,
    max_depth: u64,
    n_features: u64,
    n_used: u64,
    max_t: u64,
    n_leaf: u64,
) -> BitWriter {
    let mut w = BitWriter::new();
    w.write(task, 2);
    w.write(n_outputs, 8);
    w.write(n_rounds, 16);
    w.write(max_depth, 4);
    w.write(n_features, 16);
    w.write(n_used, 16);
    w.write(max_t, 16);
    w.write(n_leaf, 24);
    for _ in 0..n_outputs {
        w.write_f32(0.0);
    }
    w
}

/// Knobs for the hand-packed single-tree blob below. Defaults produce a
/// blob that decodes cleanly; each test perturbs one knob.
#[derive(Clone)]
struct Tiny {
    /// Width exponent + numeric-type bit of the feature-0 map entry.
    f0_exp: u64,
    f0_float: bool,
    /// Stored tree depth (`max_depth` in the header is 2).
    depth: u64,
    /// Root split: feature ref (|F_U| = 3) and threshold rank
    /// (feature 0 has 3 thresholds).
    fr: u64,
    tr: u64,
    /// Leaf-value refs (table holds 5 entries).
    lr: [u64; 2],
}

impl Default for Tiny {
    fn default() -> Self {
        Tiny { f0_exp: 0, f0_float: false, depth: 1, fr: 0, tr: 2, lr: [0, 4] }
    }
}

/// Shared header + map + threshold + leaf sections of the hand-packed
/// blobs: task 0, 1 output, 1 round, max_depth 2, 3 features (all
/// used, maxT 3), 5 leaf values. Derived widths: wd = wc = w_f = w_t =
/// 2, w_l = 3, w_dep = 2.
fn tiny_prefix(f0_exp: u64, f0_float: bool) -> BitWriter {
    let mut w = header(0, 1, 1, 2, 3, 3, 3, 5);
    // Map: (feature, exponent:3, is_float:1, count-1).
    w.write(0, 2);
    w.write(f0_exp, 3);
    w.write(u64::from(f0_float), 1);
    w.write(2, 2); // 3 thresholds
    w.write(1, 2);
    w.write(1, 3); // uint width 2
    w.write(0, 1);
    w.write(1, 2); // 2 thresholds
    w.write(2, 2);
    w.write(4, 3); // f16
    w.write(1, 1);
    w.write(0, 2); // 1 threshold
    // Threshold tables sized for the *default* map (3×1 + 2×2 + 16
    // bits); exponent-mutation tests are rejected during the map walk,
    // before sizing matters.
    w.write(0, 1);
    w.write(1, 1);
    w.write(1, 1);
    w.write(1, 2);
    w.write(2, 2);
    w.write_f16(0.5);
    for i in 0..5 {
        w.write_f32(i as f32 * 0.25);
    }
    w
}

/// Hand-pack a complete blob with one general-body tree.
fn tiny_blob(t: &Tiny) -> Vec<u8> {
    let mut w = tiny_prefix(t.f0_exp, t.f0_float);
    // One tree: flag 0 (general body), depth, complete node array.
    w.write(0, 1);
    w.write(t.depth, 2);
    let n_internal = (1usize << t.depth) - 1;
    for _ in 0..n_internal {
        w.write(t.fr, 2);
        w.write(t.tr, 2);
    }
    for s in 0..(1usize << t.depth) {
        w.write(t.lr[s % 2], 3);
    }
    w.into_bytes()
}

/// Knobs for the hand-packed *oblivious*-body blob (same header/map
/// sections as [`Tiny`]). Defaults decode cleanly; each test perturbs
/// one knob.
#[derive(Clone)]
struct TinyObl {
    /// Stored tree depth; governs both the pair count and the 2^d leaf
    /// table (`max_depth` in the header is 2).
    depth: u64,
    /// Per-level (feature ref, threshold rank), root level first.
    pairs: [(u64, u64); 2],
    /// Leaf-table refs (value table holds 5 entries).
    lr: [u64; 4],
}

impl Default for TinyObl {
    fn default() -> Self {
        TinyObl { depth: 2, pairs: [(0, 2), (1, 1)], lr: [0, 4, 1, 3] }
    }
}

/// Hand-pack a blob with one oblivious-body tree: flag 1, depth d,
/// d (feature-ref, threshold-rank) pairs, 2^d leaf refs.
fn tiny_obl_blob(t: &TinyObl) -> Vec<u8> {
    let mut w = tiny_prefix(0, false);
    w.write(1, 1);
    w.write(t.depth, 2);
    for lvl in 0..t.depth as usize {
        let (fr, tr) = t.pairs[lvl % 2];
        w.write(fr, 2);
        w.write(tr, 2);
    }
    for s in 0..(1usize << t.depth) {
        w.write(t.lr[s % 4], 3);
    }
    w.into_bytes()
}

#[test]
fn the_canonical_crafted_blob_decodes() {
    let blob = tiny_blob(&Tiny::default());
    let bits = validate_blob(&blob).expect("canonical blob must validate");
    assert!(bits <= blob.len() * 8);
    let model = try_decode(&blob).expect("canonical blob must decode");
    assert_eq!(model.n_features, 3);
    assert_eq!(model.trees.len(), 1, "one output");
    assert_eq!(model.trees[0].len(), 1, "one round");
}

#[test]
fn every_prefix_of_the_crafted_blob_is_rejected() {
    let blob = tiny_blob(&Tiny::default());
    for k in 0..blob.len() {
        assert!(
            !decodes_without_panic(&blob[..k], &format!("crafted prefix of {k} bytes")),
            "a {k}-byte prefix validated as complete"
        );
    }
}

#[test]
fn every_bit_flip_of_the_crafted_blob_is_handled() {
    // Small enough to keep in Miri runs, where the sweep doubles as
    // coverage of BitReader's unaligned read paths. Includes the flips
    // that turn stored references out of range (e.g. leaf ref 4 → 5),
    // which panicked decode before validate_blob walked tree bodies.
    let blob = tiny_blob(&Tiny::default());
    let mut flipped = blob.clone();
    for byte in 0..blob.len() {
        for bit in 0..8 {
            flipped[byte] ^= 1 << bit;
            decodes_without_panic(&flipped, &format!("crafted flip at byte {byte} bit {bit}"));
            flipped[byte] ^= 1 << bit;
        }
    }
}

fn expect_err(bytes: &[u8], needle: &str, what: &str) {
    match catch_unwind(AssertUnwindSafe(|| try_decode(bytes))) {
        Ok(Err(msg)) => {
            assert!(msg.contains(needle), "{what}: error {msg:?} lacks {needle:?}")
        }
        Ok(Ok(_)) => panic!("{what}: malformed blob decoded successfully"),
        Err(_) => panic!("{what}: try_decode panicked instead of returning Err"),
    }
}

#[test]
fn rejects_malformed_headers() {
    expect_err(&[], "blob too small", "empty blob");
    expect_err(&[0x55; 4], "blob too small", "sub-header blob");
    expect_err(&header(3, 1, 0, 0, 0, 0, 0, 0).into_bytes(), "invalid task code", "task 3");
    expect_err(&header(0, 0, 0, 0, 0, 0, 0, 0).into_bytes(), "zero outputs", "0 outputs");
    expect_err(
        &header(0, 2, 0, 0, 0, 0, 0, 0).into_bytes(),
        "requires 1 output",
        "binary task with 2 outputs",
    );
    expect_err(
        &header(0, 1, 0, 0, 1, 2, 3, 0).into_bytes(),
        "exceeds d",
        "|F_U| > d",
    );
    expect_err(
        &header(0, 1, 0, 0, 2, 1, 0, 0).into_bytes(),
        "no thresholds",
        "used features with maxT 0",
    );
    expect_err(
        &header(0, 1, 1, 0, 0, 0, 0, 0).into_bytes(),
        "without leaf values",
        "a round but an empty leaf table",
    );
}

#[test]
fn rejects_truncated_sections() {
    // Header promises a map entry that is not there.
    expect_err(&header(0, 1, 0, 0, 2, 1, 3, 1).into_bytes(), "map truncated", "missing map");
    // Map present, threshold + leaf tables missing.
    let mut w = header(0, 1, 0, 0, 2, 1, 3, 1);
    w.write(0, 1); // feature 0 (wd = 1)
    w.write(0, 3); // uint width 1
    w.write(0, 1);
    w.write(2, 2); // 3 thresholds
    expect_err(&w.into_bytes(), "truncated", "missing threshold/leaf tables");
}

#[test]
fn rejects_map_entries_that_overflow_their_tables() {
    // Feature index past d (d = 3 → wd = 2, so the field can hold 3).
    let mut w = header(0, 1, 0, 0, 3, 1, 3, 1);
    w.write(3, 2); // feature 3 of 3
    w.write(0, 3);
    w.write(0, 1);
    w.write(0, 2);
    w.write_f32(0.0); // padding so the map-size check passes
    expect_err(&w.into_bytes(), "out of range", "map feature index past d");

    // Threshold count past maxT.
    let mut w = header(0, 1, 0, 0, 2, 1, 3, 1);
    w.write(1, 1); // wd = 1, feature 1 is in range…
    w.write(0, 3);
    w.write(0, 1);
    w.write(3, 2); // …but count 4 > maxT 3
    w.write_f32(0.0);
    expect_err(&w.into_bytes(), "> maxT", "threshold count past maxT");
}

#[test]
fn rejects_invalid_width_exponents() {
    // Float thresholds narrower than f16 or wider than f32 do not
    // exist; integer widths stop at 32 bits (exp 5) — exp 6/7 would
    // demand 64/128-bit reads downstream.
    expect_err(
        &tiny_blob(&Tiny { f0_exp: 3, f0_float: true, ..Tiny::default() }),
        "invalid float width",
        "f8 thresholds",
    );
    expect_err(
        &tiny_blob(&Tiny { f0_exp: 6, f0_float: false, ..Tiny::default() }),
        "invalid integer width",
        "u64 thresholds",
    );
    expect_err(
        &tiny_blob(&Tiny { f0_exp: 7, f0_float: false, ..Tiny::default() }),
        "invalid integer width",
        "u128 thresholds",
    );
}

#[test]
fn rejects_trees_deeper_than_the_header_bound() {
    // PR 2 gates this family at encode time (a depth field must fit its
    // width); the decoder must reject the stored-side analogue: a tree
    // whose own depth field exceeds the header's max_depth.
    expect_err(
        &tiny_blob(&Tiny { depth: 3, ..Tiny::default() }),
        "> max",
        "tree depth past header max_depth",
    );
}

#[test]
fn rejects_out_of_range_references_instead_of_panicking() {
    // Pinning tests for the validator hardening: each of these passed
    // the original size-only checks and panicked inside decode (map /
    // leaf-table indexing) or seeked packed readers out of bounds.
    expect_err(
        &tiny_blob(&Tiny { fr: 3, ..Tiny::default() }),
        "feature ref",
        "node feature ref past |F_U|",
    );
    expect_err(
        &tiny_blob(&Tiny { tr: 3, ..Tiny::default() }),
        "threshold rank",
        "node threshold rank past the feature's count",
    );
    expect_err(
        &tiny_blob(&Tiny { lr: [0, 7], ..Tiny::default() }),
        "leaf ref",
        "leaf ref past the value table",
    );
    expect_err(
        &tiny_blob(&Tiny { lr: [5, 0], ..Tiny::default() }),
        "leaf ref",
        "first leaf ref just past the value table",
    );
}

// ---------------------------------------------------------------------
// Oblivious sub-format (flag 1): d (feature, threshold) pairs + a 2^d
// leaf table. Same validator contract, new reference families.
// ---------------------------------------------------------------------

#[test]
fn the_canonical_oblivious_blob_decodes() {
    let blob = tiny_obl_blob(&TinyObl::default());
    let bits = validate_blob(&blob).expect("canonical oblivious blob must validate");
    assert!(bits <= blob.len() * 8);
    let model = try_decode(&blob).expect("canonical oblivious blob must decode");
    assert_eq!(model.trees[0].len(), 1, "one round");
    let tree = &model.trees[0][0];
    assert!(
        tree.oblivious_levels().is_some(),
        "decoded oblivious body must stay level-uniform"
    );
    // Level 0: feature 0 rank 2 → uint threshold 1; level 1: feature 1
    // rank 1 → uint threshold 2. Leaf refs [0,4,1,3] → values
    // [0.0, 1.0, 0.25, 0.75].
    assert_eq!(model.predict_value(&[0.0, 0.0, 0.0]), 0.0);
    assert_eq!(model.predict_value(&[0.0, 9.0, 0.0]), 1.0);
    assert_eq!(model.predict_value(&[9.0, 0.0, 0.0]), 0.25);
    assert_eq!(model.predict_value(&[9.0, 9.0, 0.0]), 0.75);
}

#[test]
fn every_prefix_of_the_oblivious_blob_is_rejected() {
    let blob = tiny_obl_blob(&TinyObl::default());
    for k in 0..blob.len() {
        assert!(
            !decodes_without_panic(&blob[..k], &format!("oblivious prefix of {k} bytes")),
            "a {k}-byte prefix validated as complete"
        );
    }
}

#[test]
fn every_bit_flip_of_the_oblivious_blob_is_handled() {
    // Miri-runnable like the general-body sweep. Covers flips of the
    // sub-format flag itself (body re-parses under the wrong size),
    // level references, and leaf-table refs.
    let blob = tiny_obl_blob(&TinyObl::default());
    let mut flipped = blob.clone();
    for byte in 0..blob.len() {
        for bit in 0..8 {
            flipped[byte] ^= 1 << bit;
            decodes_without_panic(&flipped, &format!("oblivious flip at byte {byte} bit {bit}"));
            flipped[byte] ^= 1 << bit;
        }
    }
}

#[test]
fn rejects_oblivious_out_of_range_level_references() {
    expect_err(
        &tiny_obl_blob(&TinyObl { pairs: [(3, 0), (1, 1)], ..Default::default() }),
        "feature ref",
        "level feature ref past |F_U|",
    );
    expect_err(
        &tiny_obl_blob(&TinyObl { pairs: [(0, 2), (1, 3)], ..Default::default() }),
        "threshold rank",
        "level threshold rank past the feature's count",
    );
    // Per-feature counts apply, not just maxT: feature 2 has a single
    // threshold, so rank 1 is out of range even though 1 < maxT.
    expect_err(
        &tiny_obl_blob(&TinyObl { pairs: [(2, 1), (1, 1)], ..Default::default() }),
        "threshold rank",
        "level threshold rank past a narrow feature's count",
    );
    expect_err(
        &tiny_obl_blob(&TinyObl { lr: [0, 4, 5, 3], ..Default::default() }),
        "leaf ref",
        "oblivious leaf-table ref past the value table",
    );
}

#[test]
fn rejects_oblivious_trees_deeper_than_the_header_bound() {
    // The stored depth sizes the 2^d leaf table, so an oversized depth
    // is the oblivious "bad leaf-table size" malformation.
    expect_err(
        &tiny_obl_blob(&TinyObl { depth: 3, ..Default::default() }),
        "> max",
        "oblivious depth (and leaf table) past header max_depth",
    );
}
