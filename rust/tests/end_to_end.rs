//! End-to-end integration: train → penalize → encode → decode → packed
//! inference, across every synthetic paper dataset.

// Everything below trains real models, spawns threads, or sweeps large
// inputs - orders of magnitude too slow under the Miri interpreter.
// `tests/miri_surface.rs` holds the fast coverage that stays in Miri runs.
#![cfg(not(miri))]

use toad::data::synth::PaperDataset;
use toad::data::train_test_split;
use toad::gbdt::GbdtParams;
use toad::layout::{self, EncodeOptions, FeatureInfo, PackedModel};
use toad::toad::{train_toad, ToadParams};

#[test]
fn full_pipeline_on_every_dataset() {
    for ds in PaperDataset::TABLE1 {
        let full = ds.generate(1);
        let n = full.n_rows().min(2000);
        let data = full.select(&(0..n).collect::<Vec<_>>());
        let (train_set, test_set) = train_test_split(&data, 0.2, 1);

        let params = ToadParams::new(GbdtParams::paper(16, 2), 1.0, 0.5);
        let toad_model = train_toad(&train_set, &params);

        // Encode → decode → scores must survive the layout round trip.
        let finfo = FeatureInfo::from_dataset(&train_set);
        let blob = layout::encode(&toad_model.model, &finfo, &EncodeOptions::default()).unwrap();
        assert_eq!(blob.len(), toad_model.size_bytes(), "{}", ds.name());

        let decoded = layout::decode(&blob);
        let s_orig = toad_model.model.score(&test_set);
        let s_dec = decoded.score(&test_set);
        assert!(
            (s_orig - s_dec).abs() < 0.02,
            "{}: score moved through layout: {s_orig} vs {s_dec}",
            ds.name()
        );

        // Packed (bit-level) inference must agree with the decoded model.
        let packed = PackedModel::from_bytes(blob);
        for i in (0..test_set.n_rows()).step_by(97) {
            let x = test_set.row(i);
            let a = decoded.predict_raw(&x);
            let b = packed.predict_raw(&x);
            for (p, q) in a.iter().zip(&b) {
                assert!((p - q).abs() < 1e-5, "{} row {i}", ds.name());
            }
        }

        // The ToaD blob must undercut the float32 pointer layout.
        let ptr = layout::baseline::pointer_f32_bytes(&toad_model.model);
        assert!(
            toad_model.size_bytes() < ptr,
            "{}: toad {} >= pointer {}",
            ds.name(),
            toad_model.size_bytes(),
            ptr
        );
    }
}

#[test]
fn compression_ratio_vs_lightgbm_is_substantial() {
    // The paper's headline: 4–16x smaller at equal performance. Here we
    // check the layout-level ratio at equal model structure (same trees):
    // ToaD encoding vs 128-bit pointer nodes.
    let data = PaperDataset::CovertypeBinary.generate(2);
    let data = data.select(&(0..4000).collect::<Vec<_>>());
    let (train_set, _) = train_test_split(&data, 0.2, 1);
    let params = ToadParams::new(GbdtParams::paper(32, 3), 4.0, 2.0);
    let m = train_toad(&train_set, &params);
    let ptr = layout::baseline::pointer_f32_bytes(&m.model);
    let ratio = ptr as f64 / m.size_bytes() as f64;
    assert!(ratio > 3.0, "compression ratio {ratio:.2} below expectation");
}
