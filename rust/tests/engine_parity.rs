//! Cross-engine parity: the four native prediction paths must agree on
//! randomly grown models.
//!
//! * `FlatModel::predict_batch` vs `Tree::predict_row` (through
//!   `GbdtModel::predict_raw`): **bit-identical** — the flat engine
//!   performs the same comparisons and sums leaf contributions in the
//!   same order, so the bound here is 1e-9 with exactness expected.
//! * `QuantizedFlatModel` vs `FlatModel`: **bit-identical** — the rank
//!   predicate `bin(x) ≤ rank(t)` is exactly equivalent to `x ≤ t` for
//!   every real input, and NaN maps to a sentinel bin that routes right
//!   exactly like `!(x ≤ t)` on floats.
//! * `QuantizedFlatModel::predict_batch_columns` vs the row-major
//!   paths: **bit-identical** — the columnar path bins each feature
//!   column once into the shared `BinMatrix` arena and then runs the
//!   same blocked descent kernel, so routing and summation order are
//!   the same by construction (NaN columns included).
//! * `PackedModel::predict_raw` vs the pointer trees: the packed layout
//!   stores leaf values as f32 (paper §3.2.2), so each tree contributes
//!   one f32 rounding; the bound scales with the ensemble size (1e-4 is
//!   generous for ≤ 64 small trees).
//!
//! Every property also injects NaN feature values: all engines must
//! route NaN right at every split (the `x ≤ t` predicate is false).
//!
//! The SIMD properties additionally pin the dispatch tiers against each
//! other: `QuantizedFlatModel::predict_batch_with_tier` must be
//! **bit-identical** on every tier the CPU supports (scalar, SSE2,
//! AVX2), across NaN rows, every lane-tail length, and both `BinMatrix`
//! arena widths on the columnar path.

// Everything below trains real models, spawns threads, or sweeps large
// inputs - orders of magnitude too slow under the Miri interpreter.
// `tests/miri_surface.rs` holds the fast coverage that stays in Miri runs.
#![cfg(not(miri))]

use toad::gbdt::loss::Objective;
use toad::gbdt::{booster, GbdtModel, GbdtParams, Node, Tree};
use toad::inference::{FlatModel, QuantizedFlatModel};
use toad::layout::{encode, EncodeOptions, FeatureInfo, PackedModel};
use toad::simd::{self, Tier};
use toad::testutil::prop::run_prop;

#[test]
fn engines_agree_on_randomly_grown_models() {
    run_prop("flat/quantized/pointer/packed engine parity", 15, |g| {
        let data = g.regression_dataset(60, 250, 6);
        let rounds = g.usize_in(1, 8);
        let depth = g.usize_in(1, 5);
        let params = GbdtParams {
            min_data_in_leaf: g.usize_in(1, 10) as u32,
            ..GbdtParams::paper(rounds, depth)
        };
        let model = booster::train(&data, params);

        let flat = FlatModel::from_model(&model);
        let quant = QuantizedFlatModel::from_model(&model);
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(
            &model,
            &finfo,
            &EncodeOptions { allow_f16: false, leaf_mantissa_bits: None },
        )
        .expect("grown models fit the layout's header fields");
        let packed = PackedModel::from_bytes(blob);

        // Training rows plus a few NaN-corrupted copies.
        let mut rows: Vec<Vec<f32>> = (0..data.n_rows()).map(|i| data.row(i)).collect();
        for _ in 0..8 {
            let mut r = data.row(g.usize(data.n_rows()));
            let f = g.usize(r.len());
            r[f] = f32::NAN;
            rows.push(r);
        }
        let batch = flat.predict_batch(&rows);
        let qbatch = quant.predict_batch(&rows);
        // Columnar batch over the transposed rows must match bit for bit.
        let cols: Vec<Vec<f32>> = (0..data.n_features())
            .map(|f| rows.iter().map(|r| r[f]).collect())
            .collect();
        let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        let cbatch = quant.predict_batch_columns(&col_refs, rows.len());
        assert_eq!(batch.len(), rows.len());
        assert_eq!(cbatch.len(), rows.len());
        for (i, row) in rows.iter().enumerate() {
            let pointer = model.predict_raw(row);
            let single = flat.predict_raw(row);
            let packed_out = packed.predict_raw(row);
            assert!(
                (batch[i][0] - pointer[0]).abs() < 1e-9,
                "row {i}: flat batch {} vs pointer {}",
                batch[i][0],
                pointer[0]
            );
            assert_eq!(
                batch[i], single,
                "row {i}: blocked batch and single-row flat paths diverged"
            );
            assert_eq!(
                qbatch[i], batch[i],
                "row {i}: quantized batch must be bit-identical to flat"
            );
            assert_eq!(
                cbatch[i], qbatch[i],
                "row {i}: columnar batch must be bit-identical to the row batch"
            );
            assert_eq!(
                quant.predict_raw(row),
                single,
                "row {i}: quantized single-row must be bit-identical to flat"
            );
            assert!(
                (packed_out[0] - pointer[0]).abs() < 1e-4,
                "row {i}: packed {} vs pointer {} (beyond f32 leaf rounding)",
                packed_out[0],
                pointer[0]
            );
        }
    });
}

/// Off-dataset probes (values the binner never saw, plus NaN-corrupted
/// ones) must route the same way through all engines too.
#[test]
fn engines_agree_on_off_data_probes() {
    run_prop("engine parity off-data", 10, |g| {
        let data = g.regression_dataset(80, 160, 4);
        let model = booster::train(&data, GbdtParams::paper(4, 3));
        let flat = FlatModel::from_model(&model);
        let quant = QuantizedFlatModel::from_model(&model);
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(
            &model,
            &finfo,
            &EncodeOptions { allow_f16: false, leaf_mantissa_bits: None },
        )
        .expect("grown models fit the layout's header fields");
        let packed = PackedModel::from_bytes(blob);

        let d = data.n_features();
        let probes: Vec<Vec<f32>> = (0..32)
            .map(|_| {
                (0..d)
                    .map(|_| {
                        if g.bool(0.1) {
                            f32::NAN
                        } else {
                            g.f64_in(-3.0, 3.0) as f32
                        }
                    })
                    .collect()
            })
            .collect();
        let batch = flat.predict_batch(&probes);
        let qbatch = quant.predict_batch(&probes);
        let cols: Vec<Vec<f32>> =
            (0..d).map(|f| probes.iter().map(|r| r[f]).collect()).collect();
        let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        let cbatch = quant.predict_batch_columns(&col_refs, probes.len());
        for (i, probe) in probes.iter().enumerate() {
            let pointer = model.predict_raw(probe);
            assert!((batch[i][0] - pointer[0]).abs() < 1e-9, "probe {i}");
            assert_eq!(qbatch[i], batch[i], "probe {i}: quantized vs flat");
            assert_eq!(cbatch[i], qbatch[i], "probe {i}: columnar vs row batch");
            assert!((packed.predict_raw(probe)[0] - pointer[0]).abs() < 1e-4, "probe {i}");
        }
    });
}

/// Every SIMD dispatch tier must produce bit-identical batches to the
/// forced-scalar twin on trained models — row path and columnar path,
/// NaN rows included, with row counts sweeping the lane-group tails.
#[test]
fn prop_simd_descent_tiers_match_forced_scalar() {
    run_prop("simd descent tiers == forced scalar", 10, |g| {
        let data = g.regression_dataset(40, 200, 6);
        let rounds = g.usize_in(2, 8);
        let depth = g.usize_in(1, 5);
        let model = booster::train(&data, GbdtParams::paper(rounds, depth));
        let quant = QuantizedFlatModel::from_model(&model);
        // Half the cases use 1..=17 rows (every tail length of both the
        // 8- and 16-lane kernels), half use bigger multi-group blocks.
        let n_rows = if g.bool(0.5) { g.usize_in(1, 17) } else { g.usize_in(18, 80) };
        let rows: Vec<Vec<f32>> = (0..n_rows)
            .map(|i| {
                let mut r = data.row(i % data.n_rows());
                if g.bool(0.3) {
                    let f = g.usize(r.len());
                    r[f] = f32::NAN;
                }
                r
            })
            .collect();
        let cols: Vec<Vec<f32>> =
            (0..data.n_features()).map(|f| rows.iter().map(|r| r[f]).collect()).collect();
        let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        let want = quant.predict_batch_with_tier(&rows, Tier::Scalar);
        // Ground truth: the forced-scalar twin matches the pointer trees.
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(want[i], model.predict_raw(row), "scalar tier vs pointer, row {i}");
        }
        for tier in simd::available_tiers() {
            assert_eq!(
                quant.predict_batch_with_tier(&rows, tier),
                want,
                "row batch, tier {}",
                tier.name()
            );
            assert_eq!(
                quant.predict_batch_columns_with_tier(&col_refs, n_rows, tier),
                want,
                "columnar batch, tier {}",
                tier.name()
            );
        }
        // A tier the CPU may lack must clamp, never crash or diverge.
        assert_eq!(quant.predict_batch_with_tier(&rows, Tier::Avx2), want);
    });
}

/// Build a perfect level-uniform tree: `levels[ℓ]` is the (feature,
/// threshold) shared by every internal node on level ℓ, `leaves[s]` the
/// value at MSB-first path slot `s`. Such trees are exactly what the
/// quantized engine's oblivious detector accepts.
fn level_uniform_tree(levels: &[(usize, f32)], leaves: &[f64]) -> Tree {
    fn build(nodes: &mut Vec<Node>, levels: &[(usize, f32)], leaves: &[f64], slot: usize) -> usize {
        let Some(&(feature, threshold)) = levels.first() else {
            let idx = nodes.len();
            nodes.push(Node::Leaf { value: leaves[slot] });
            return idx;
        };
        let idx = nodes.len();
        nodes.push(Node::Internal { feature, bin: 0, threshold, left: 0, right: 0 });
        let l = build(nodes, &levels[1..], leaves, slot * 2);
        let r = build(nodes, &levels[1..], leaves, slot * 2 + 1);
        if let Node::Internal { left, right, .. } = &mut nodes[idx] {
            *left = l;
            *right = r;
        }
        idx
    }
    let mut nodes = Vec::new();
    build(&mut nodes, levels, leaves, 0);
    Tree { nodes }
}

/// Oblivious sub-format parity: level-uniform trees route through the
/// table-lookup descent in the quantized engine and must stay
/// bit-identical to the generic complete-layout kernel (`FlatModel`
/// never constructs the oblivious layout — it replicates the same trees
/// as dense complete blocks) and to the pointer trees — on every
/// available SIMD tier plus the forced-scalar twin, across NaN rows,
/// and on every ragged tail length 1..=17 of both lane widths.
#[test]
fn oblivious_descent_matches_generic_complete_kernel_on_every_tier() {
    let trees = vec![
        level_uniform_tree(&[(0, 0.3), (1, -0.4)], &[0.1, -0.2, 0.3, -0.4]),
        level_uniform_tree(
            &[(1, 0.9), (0, -1.1), (1, 0.15)],
            &[1.0, -1.0, 0.5, -0.5, 0.25, -0.25, 0.125, -0.125],
        ),
        level_uniform_tree(
            &[(0, -0.05), (0, 0.65), (1, -0.9), (1, 1.3)],
            &(0..16).map(|i| i as f64 * 0.0625 - 0.5).collect::<Vec<_>>(),
        ),
    ];
    let model = GbdtModel {
        objective: Objective::L2,
        base_scores: vec![0.05],
        trees: vec![trees],
        n_features: 2,
        name: "oblivious-parity".into(),
    };
    let quant = QuantizedFlatModel::from_model(&model);
    assert_eq!(quant.n_oblivious_trees(), 3, "every tree is level-uniform");
    let flat = FlatModel::from_model(&model);

    // Probe rows straddle every threshold; NaN injected on both
    // features (NaN must route right at each level, same as `!(x ≤ t)`).
    let all_rows: Vec<Vec<f32>> = (0..70)
        .map(|i| {
            let x = -1.7 + 0.053 * i as f32;
            let y = -1.3 + 0.041 * i as f32;
            match i % 7 {
                0 => vec![f32::NAN, y],
                3 => vec![x, f32::NAN],
                6 => vec![f32::NAN, f32::NAN],
                _ => vec![x, y],
            }
        })
        .collect();
    for n in (1..=17).chain([31, 32, 33, 64, 70]) {
        let rows = &all_rows[..n];
        let cols: Vec<Vec<f32>> = (0..2).map(|f| rows.iter().map(|r| r[f]).collect()).collect();
        let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        let want = quant.predict_batch_with_tier(rows, Tier::Scalar);
        let complete = flat.predict_batch(rows);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(want[i], model.predict_raw(row), "n={n} row {i}: oblivious vs pointer");
            assert_eq!(
                want[i], complete[i],
                "n={n} row {i}: oblivious descent vs generic complete kernel"
            );
        }
        for tier in simd::available_tiers() {
            assert_eq!(
                quant.predict_batch_with_tier(rows, tier),
                want,
                "n={n}, tier {}",
                tier.name()
            );
            assert_eq!(
                quant.predict_batch_columns_with_tier(&col_refs, n, tier),
                want,
                "n={n} columnar, tier {}",
                tier.name()
            );
        }
        // A tier the CPU may lack must clamp, never crash or diverge.
        assert_eq!(quant.predict_batch_with_tier(rows, Tier::Avx2), want);
    }
}

/// Deterministic tier parity on a handmade model whose feature 0 uses
/// 300 distinct thresholds — more than 256 bins, so the columnar path's
/// `BinMatrix` arena is forced to `u16` width (the trained-model
/// property above stays in the common `u8` regime). Also walks every
/// tail length 1..=17 explicitly and includes a deep general-layout
/// tree so the block kernel mixes complete and node descents.
#[test]
fn simd_tiers_agree_on_wide_threshold_tables_and_every_tail_length() {
    let mut trees = Vec::new();
    for k in 0..300u32 {
        let t = -1.5 + 0.01 * k as f32;
        trees.push(Tree {
            nodes: vec![
                Node::Internal { feature: 0, bin: 0, threshold: t, left: 1, right: 2 },
                Node::Leaf { value: 0.25 + k as f64 * 0.001 },
                Node::Leaf { value: -0.5 + k as f64 * 0.002 },
            ],
        });
    }
    // A depth-14 left-leaning chain on feature 1: too deep for the
    // complete layout, so it takes the general node path in the block.
    let mut nodes = Vec::new();
    for d in 0..14usize {
        let idx = nodes.len();
        nodes.push(Node::Internal {
            feature: 1,
            bin: d as u16,
            threshold: -(d as f32) * 0.1,
            left: idx + 2,
            right: idx + 1,
        });
        nodes.push(Node::Leaf { value: d as f64 });
    }
    nodes.push(Node::Leaf { value: -7.0 });
    trees.push(Tree { nodes });
    let model = GbdtModel {
        objective: Objective::L2,
        base_scores: vec![0.1],
        trees: vec![trees],
        n_features: 2,
        name: "simd-wide".into(),
    };
    let quant = QuantizedFlatModel::from_model(&model);
    assert!(quant.n_thresholds() > 256, "feature 0 must overflow the u8 arena");

    // Probe rows straddle threshold boundaries; every 7th has a NaN.
    let all_rows: Vec<Vec<f32>> = (0..70)
        .map(|i| {
            let x = -2.0 + 0.037 * i as f32;
            let y = -1.6 + 0.11 * i as f32;
            match i % 7 {
                0 => vec![f32::NAN, y],
                3 => vec![x, f32::NAN],
                _ => vec![x, y],
            }
        })
        .collect();
    for n in (1..=17).chain([31, 32, 33, 63, 64, 65, 70]) {
        let rows = &all_rows[..n];
        let cols: Vec<Vec<f32>> =
            (0..2).map(|f| rows.iter().map(|r| r[f]).collect()).collect();
        let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        let want = quant.predict_batch_with_tier(rows, Tier::Scalar);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(want[i], model.predict_raw(row), "n={n} row {i} vs pointer");
        }
        for tier in simd::available_tiers() {
            assert_eq!(
                quant.predict_batch_with_tier(rows, tier),
                want,
                "n={n}, tier {}",
                tier.name()
            );
            assert_eq!(
                quant.predict_batch_columns_with_tier(&col_refs, n, tier),
                want,
                "n={n} columnar (u16 arena), tier {}",
                tier.name()
            );
        }
    }
}
