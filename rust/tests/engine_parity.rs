//! Cross-engine parity: the four native prediction paths must agree on
//! randomly grown models.
//!
//! * `FlatModel::predict_batch` vs `Tree::predict_row` (through
//!   `GbdtModel::predict_raw`): **bit-identical** — the flat engine
//!   performs the same comparisons and sums leaf contributions in the
//!   same order, so the bound here is 1e-9 with exactness expected.
//! * `QuantizedFlatModel` vs `FlatModel`: **bit-identical** — the rank
//!   predicate `bin(x) ≤ rank(t)` is exactly equivalent to `x ≤ t` for
//!   every real input, and NaN maps to a sentinel bin that routes right
//!   exactly like `!(x ≤ t)` on floats.
//! * `QuantizedFlatModel::predict_batch_columns` vs the row-major
//!   paths: **bit-identical** — the columnar path bins each feature
//!   column once into the shared `BinMatrix` arena and then runs the
//!   same blocked descent kernel, so routing and summation order are
//!   the same by construction (NaN columns included).
//! * `PackedModel::predict_raw` vs the pointer trees: the packed layout
//!   stores leaf values as f32 (paper §3.2.2), so each tree contributes
//!   one f32 rounding; the bound scales with the ensemble size (1e-4 is
//!   generous for ≤ 64 small trees).
//!
//! Every property also injects NaN feature values: all engines must
//! route NaN right at every split (the `x ≤ t` predicate is false).

use toad::gbdt::{booster, GbdtParams};
use toad::inference::{FlatModel, QuantizedFlatModel};
use toad::layout::{encode, EncodeOptions, FeatureInfo, PackedModel};
use toad::testutil::prop::run_prop;

#[test]
fn engines_agree_on_randomly_grown_models() {
    run_prop("flat/quantized/pointer/packed engine parity", 15, |g| {
        let data = g.regression_dataset(60, 250, 6);
        let rounds = g.usize_in(1, 8);
        let depth = g.usize_in(1, 5);
        let params = GbdtParams {
            min_data_in_leaf: g.usize_in(1, 10) as u32,
            ..GbdtParams::paper(rounds, depth)
        };
        let model = booster::train(&data, params);

        let flat = FlatModel::from_model(&model);
        let quant = QuantizedFlatModel::from_model(&model);
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(
            &model,
            &finfo,
            &EncodeOptions { allow_f16: false, leaf_mantissa_bits: None },
        )
        .expect("grown models fit the layout's header fields");
        let packed = PackedModel::from_bytes(blob);

        // Training rows plus a few NaN-corrupted copies.
        let mut rows: Vec<Vec<f32>> = (0..data.n_rows()).map(|i| data.row(i)).collect();
        for _ in 0..8 {
            let mut r = data.row(g.usize(data.n_rows()));
            let f = g.usize(r.len());
            r[f] = f32::NAN;
            rows.push(r);
        }
        let batch = flat.predict_batch(&rows);
        let qbatch = quant.predict_batch(&rows);
        // Columnar batch over the transposed rows must match bit for bit.
        let cols: Vec<Vec<f32>> = (0..data.n_features())
            .map(|f| rows.iter().map(|r| r[f]).collect())
            .collect();
        let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        let cbatch = quant.predict_batch_columns(&col_refs, rows.len());
        assert_eq!(batch.len(), rows.len());
        assert_eq!(cbatch.len(), rows.len());
        for (i, row) in rows.iter().enumerate() {
            let pointer = model.predict_raw(row);
            let single = flat.predict_raw(row);
            let packed_out = packed.predict_raw(row);
            assert!(
                (batch[i][0] - pointer[0]).abs() < 1e-9,
                "row {i}: flat batch {} vs pointer {}",
                batch[i][0],
                pointer[0]
            );
            assert_eq!(
                batch[i], single,
                "row {i}: blocked batch and single-row flat paths diverged"
            );
            assert_eq!(
                qbatch[i], batch[i],
                "row {i}: quantized batch must be bit-identical to flat"
            );
            assert_eq!(
                cbatch[i], qbatch[i],
                "row {i}: columnar batch must be bit-identical to the row batch"
            );
            assert_eq!(
                quant.predict_raw(row),
                single,
                "row {i}: quantized single-row must be bit-identical to flat"
            );
            assert!(
                (packed_out[0] - pointer[0]).abs() < 1e-4,
                "row {i}: packed {} vs pointer {} (beyond f32 leaf rounding)",
                packed_out[0],
                pointer[0]
            );
        }
    });
}

/// Off-dataset probes (values the binner never saw, plus NaN-corrupted
/// ones) must route the same way through all engines too.
#[test]
fn engines_agree_on_off_data_probes() {
    run_prop("engine parity off-data", 10, |g| {
        let data = g.regression_dataset(80, 160, 4);
        let model = booster::train(&data, GbdtParams::paper(4, 3));
        let flat = FlatModel::from_model(&model);
        let quant = QuantizedFlatModel::from_model(&model);
        let finfo = FeatureInfo::from_dataset(&data);
        let blob = encode(
            &model,
            &finfo,
            &EncodeOptions { allow_f16: false, leaf_mantissa_bits: None },
        )
        .expect("grown models fit the layout's header fields");
        let packed = PackedModel::from_bytes(blob);

        let d = data.n_features();
        let probes: Vec<Vec<f32>> = (0..32)
            .map(|_| {
                (0..d)
                    .map(|_| {
                        if g.bool(0.1) {
                            f32::NAN
                        } else {
                            g.f64_in(-3.0, 3.0) as f32
                        }
                    })
                    .collect()
            })
            .collect();
        let batch = flat.predict_batch(&probes);
        let qbatch = quant.predict_batch(&probes);
        let cols: Vec<Vec<f32>> =
            (0..d).map(|f| probes.iter().map(|r| r[f]).collect()).collect();
        let col_refs: Vec<&[f32]> = cols.iter().map(|c| c.as_slice()).collect();
        let cbatch = quant.predict_batch_columns(&col_refs, probes.len());
        for (i, probe) in probes.iter().enumerate() {
            let pointer = model.predict_raw(probe);
            assert!((batch[i][0] - pointer[0]).abs() < 1e-9, "probe {i}");
            assert_eq!(qbatch[i], batch[i], "probe {i}: quantized vs flat");
            assert_eq!(cbatch[i], qbatch[i], "probe {i}: columnar vs row batch");
            assert!((packed.predict_raw(probe)[0] - pointer[0]).abs() < 1e-4, "probe {i}");
        }
    });
}
