//! Sharded histogram-build parity: the feature-sharded parallel kernel
//! must be **bit-identical** to the scalar oracle for every shard
//! count. Within each feature the accumulation order is the same row
//! order in every kernel, so the f64 sums must match exactly — not
//! just to a tolerance.
//!
//! Shard counts cover the degenerate (1 = sequential), typical (2, 3)
//! and oversubscribed (7 > most feature counts, forcing the clamp)
//! cases; row sets cover the whole dataset (dense fast path), random
//! subsets (gathered path), single rows, and the empty leaf. The SIMD
//! property additionally pins every dispatch tier (scalar, SSE2, AVX2)
//! of the accumulators — alone, sharded, and pooled — against the same
//! oracle on both the u8 and u16 arenas.

// Everything below trains real models, spawns threads, or sweeps large
// inputs - orders of magnitude too slow under the Miri interpreter.
// `tests/miri_surface.rs` holds the fast coverage that stays in Miri runs.
#![cfg(not(miri))]

use toad::data::BinMatrix;
use toad::gbdt::histogram::{HistogramPool, HistogramSet};
use toad::testutil::prop::run_prop;

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];

fn assert_bit_identical(want: &HistogramSet, got: &HistogramSet, ctx: &str) {
    for f in 0..want.n_features() {
        for b in 0..want.n_bins(f) {
            let (g0, h0, c0) = want.bin(f, b);
            let (g1, h1, c1) = got.bin(f, b);
            assert_eq!(c0, c1, "{ctx}: count mismatch f={f} b={b}");
            assert_eq!(g0.to_bits(), g1.to_bits(), "{ctx}: grad bits f={f} b={b} {g0} vs {g1}");
            assert_eq!(h0.to_bits(), h1.to_bits(), "{ctx}: hess bits f={f} b={b} {h0} vs {h1}");
        }
    }
}

#[test]
fn prop_sharded_build_matches_scalar() {
    run_prop("sharded histogram == scalar histogram", 40, |g| {
        let n = g.usize_in(1, 400);
        let d = g.usize_in(1, 9);
        // Occasionally force a wide feature so the u16 arena path is
        // sharded too, not only the common u8 one.
        let bins_per: Vec<usize> = (0..d)
            .map(|_| if g.bool(0.15) { g.usize_in(260, 400) } else { g.usize_in(1, 16) })
            .collect();
        let binned = BinMatrix::from_fn(n, &bins_per, |f, _| g.usize(bins_per[f]) as u16);
        let grad: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let hess: Vec<f64> = (0..n).map(|_| g.f64_in(0.01, 2.0)).collect();

        let all: Vec<u32> = (0..n as u32).collect();
        let subset: Vec<u32> = all.iter().copied().filter(|_| g.bool(0.5)).collect();
        let single: Vec<u32> = vec![g.usize(n) as u32];
        let empty: Vec<u32> = Vec::new(); // an empty leaf's row set

        for rows in [&all, &subset, &single, &empty] {
            let mut scalar = HistogramSet::new(&bins_per);
            scalar.build_scalar(&binned, rows, &grad, &hess);
            for k in SHARD_COUNTS {
                let ctx = format!("d={d} n={n} rows={} shards={k}", rows.len());
                let mut sharded = HistogramSet::new(&bins_per);
                sharded.build_sharded(&binned, rows, &grad, &hess, k);
                assert_bit_identical(&scalar, &sharded, &ctx);
                // The pooled path (shared gather scratch, recycled
                // buffers) must agree too.
                let mut pool = HistogramPool::with_shards(&bins_per, k);
                let pooled = pool.build(&binned, rows, &grad, &hess);
                assert_bit_identical(&scalar, &pooled, &format!("{ctx} (pool)"));
                pool.recycle(pooled);
                let reused = pool.build(&binned, rows, &grad, &hess);
                assert_bit_identical(&scalar, &reused, &format!("{ctx} (recycled)"));
            }
        }
    });
}

#[test]
fn auto_selected_shards_match_scalar_bitwise() {
    // The auto-selected count (GbdtParams::histogram_shards = 0 →
    // auto_shards(width)) must be bit-identical to the scalar oracle
    // like every manual count — on this machine's actual parallelism.
    use toad::gbdt::histogram::{auto_shards, AUTO_SHARD_MAX};
    use toad::gbdt::GbdtParams;
    run_prop("auto-sharded histogram == scalar histogram", 10, |g| {
        let n = g.usize_in(1, 400);
        let d = g.usize_in(1, 40);
        let k = GbdtParams::default().resolved_shards(d);
        assert_eq!(k, auto_shards(d), "params must delegate to auto_shards");
        assert!(k >= 1 && k <= d.max(1) && k <= AUTO_SHARD_MAX, "auto count {k} for {d}");
        let bins_per: Vec<usize> = (0..d).map(|_| g.usize_in(1, 16)).collect();
        let binned = BinMatrix::from_fn(n, &bins_per, |f, _| g.usize(bins_per[f]) as u16);
        let grad: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let hess: Vec<f64> = (0..n).map(|_| g.f64_in(0.01, 2.0)).collect();
        let rows: Vec<u32> = (0..n as u32).collect();
        let mut scalar = HistogramSet::new(&bins_per);
        scalar.build_scalar(&binned, &rows, &grad, &hess);
        let mut pool = HistogramPool::with_shards(&bins_per, k);
        let auto = pool.build(&binned, &rows, &grad, &hess);
        assert_bit_identical(&scalar, &auto, &format!("auto shards k={k} d={d} n={n}"));
    });
}

#[test]
fn sharded_single_feature_clamps_and_matches() {
    // One feature cannot be split across shards: every k clamps to the
    // sequential build and must still be exact.
    let bins_per = [5usize];
    let binned = BinMatrix::from_u16_columns(vec![vec![0, 4, 2, 2, 1, 3, 0, 4, 1, 2]]);
    let grad: Vec<f64> = (0..10).map(|i| (i as f64) * 0.37 - 1.5).collect();
    let hess: Vec<f64> = (0..10).map(|i| 0.1 + (i as f64) * 0.01).collect();
    let rows: Vec<u32> = (0..10).collect();
    let mut scalar = HistogramSet::new(&bins_per);
    scalar.build_scalar(&binned, &rows, &grad, &hess);
    for k in SHARD_COUNTS {
        let mut sharded = HistogramSet::new(&bins_per);
        sharded.build_sharded(&binned, &rows, &grad, &hess, k);
        assert_bit_identical(&scalar, &sharded, &format!("single feature, k={k}"));
    }
}

#[test]
fn sharded_empty_row_set_yields_zero_histogram() {
    let bins_per = [3usize, 2, 300];
    let binned = BinMatrix::from_fn(6, &bins_per, |f, i| ((i + f) % bins_per[f]) as u16);
    assert!(!binned.is_u8(), "300-bin feature must force the u16 arena");
    let grad = vec![1.0; 6];
    let hess = vec![1.0; 6];
    for k in SHARD_COUNTS {
        let mut h = HistogramSet::new(&bins_per);
        // Dirty the buffer first so the zeroing is actually exercised.
        h.build(&binned, &[0, 1, 2], &grad, &hess);
        h.build_sharded(&binned, &[], &grad, &hess, k);
        for f in 0..3 {
            for b in 0..h.n_bins(f) {
                assert_eq!(h.bin(f, b), (0.0, 0.0, 0), "k={k} f={f} b={b}");
            }
        }
    }
}

/// Every SIMD dispatch tier of the accumulators — dense, gathered,
/// sharded, and pooled (recycled buffers included) — must be
/// **bit-identical** to the scalar oracle on both arena widths, with
/// row counts sweeping the 4/8/16-lane tails and row sets covering the
/// whole dataset, random subsets, a single row, and the empty leaf.
#[test]
fn prop_simd_histogram_tiers_match_scalar_oracle() {
    use toad::simd::{self, Tier};
    run_prop("simd histogram tiers == scalar oracle", 25, |g| {
        // Tail-heavy half: 1..=40 rows crosses every lane-group width.
        let n = if g.bool(0.5) { g.usize_in(1, 40) } else { g.usize_in(41, 300) };
        let d = g.usize_in(1, 6);
        // Occasionally force a wide feature so the u16 arena kernels
        // are exercised alongside the common u8 ones.
        let bins_per: Vec<usize> = (0..d)
            .map(|_| if g.bool(0.15) { g.usize_in(260, 400) } else { g.usize_in(2, 16) })
            .collect();
        let binned = BinMatrix::from_fn(n, &bins_per, |f, _| g.usize(bins_per[f]) as u16);
        let grad: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let hess: Vec<f64> = (0..n).map(|_| g.f64_in(0.01, 2.0)).collect();
        let all: Vec<u32> = (0..n as u32).collect();
        let subset: Vec<u32> = all.iter().copied().filter(|_| g.bool(0.5)).collect();
        let single: Vec<u32> = vec![g.usize(n) as u32];
        let empty: Vec<u32> = Vec::new();
        for rows in [&all, &subset, &single, &empty] {
            let mut oracle = HistogramSet::new(&bins_per);
            oracle.build_scalar(&binned, rows, &grad, &hess);
            for tier in simd::available_tiers() {
                let ctx = format!("tier={} rows={} n={n}", tier.name(), rows.len());
                let mut tiered = HistogramSet::new(&bins_per);
                tiered.build_with_tier(&binned, rows, &grad, &hess, tier);
                assert_bit_identical(&oracle, &tiered, &ctx);
                // Sharding composes with the SIMD tiers bit-exactly.
                let mut sharded = HistogramSet::new(&bins_per);
                sharded.build_sharded_with_tier(&binned, rows, &grad, &hess, 3, tier);
                assert_bit_identical(&oracle, &sharded, &format!("{ctx} (sharded x3)"));
                // Pool path, including a recycled (dirty) buffer.
                let mut pool = HistogramPool::new(&bins_per);
                let built = pool.build_with_tier(&binned, rows, &grad, &hess, tier);
                assert_bit_identical(&oracle, &built, &format!("{ctx} (pool)"));
                pool.recycle(built);
                let reused = pool.build_with_tier(&binned, rows, &grad, &hess, tier);
                assert_bit_identical(&oracle, &reused, &format!("{ctx} (recycled)"));
            }
            // Forcing a tier the CPU may lack clamps, never crashes.
            let mut clamped = HistogramSet::new(&bins_per);
            clamped.build_with_tier(&binned, rows, &grad, &hess, Tier::Avx2);
            assert_bit_identical(&oracle, &clamped, "forced avx2 clamps");
        }
    });
}
