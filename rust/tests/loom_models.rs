//! Loom models of the registry's cross-thread contract.
//!
//! Compiled and run only under `RUSTFLAGS="--cfg loom"`:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release loom_
//! ```
//!
//! Under that cfg the `toad::sync` shim swaps `std::sync` for loom's
//! instrumented types throughout `coordinator::{metrics, registry,
//! batcher}`, and `loom::model` exhaustively explores every thread
//! interleaving (and every allowed relaxed-memory outcome) of the
//! bodies below. The in-module models for the version-counter table
//! and the batcher queue/close protocol live next to their code in
//! `src/coordinator/{metrics,batcher}.rs`; this file models the
//! `ModelRegistry` because its scenario needs a real trained
//! deployment artifact, which the integration-test layer can build
//! once and clone into every explored interleaving.
#![cfg(loom)]

use loom::thread;
use std::sync::Arc;
use toad::coordinator::planner::ModelCard;
use toad::coordinator::registry::ModelRegistry;
use toad::data::synth::PaperDataset;
use toad::gbdt::{self, GbdtParams};
use toad::inference::QuantizedFlatModel;
use toad::layout::{encode, EncodeOptions, FeatureInfo};

/// One real deployment artifact (trained once, outside the model —
/// `ModelCard` and `QuantizedFlatModel` are `Clone`, so each explored
/// interleaving gets a cheap copy, not a retrain).
fn fixture(id: &str, rounds: usize) -> (ModelCard, QuantizedFlatModel) {
    let data = PaperDataset::BreastCancer.generate(11).select(&(0..150).collect::<Vec<_>>());
    let model = gbdt::booster::train(&data, GbdtParams::paper(rounds, 2));
    let finfo = FeatureInfo::from_dataset(&data);
    let blob = encode(&model, &finfo, &EncodeOptions::default()).unwrap();
    let card = ModelCard { id: id.into(), score: 0.9, size_bytes: blob.len(), blob };
    (card, model.quantize())
}

/// Two threads race `publish` to the same key while a reader polls
/// `version_of` twice. In every interleaving: the two publishes draw
/// distinct versions, the reader never observes the live version going
/// backwards, and after both joins the key serves the higher version
/// (the registry assigns versions inside the write critical section —
/// the property `publish`'s ordering comment cites this test for).
#[test]
fn loom_registry_publish_versions_are_monotonic_per_key() {
    let (card_a, engine_a) = fixture("a", 2);
    let (card_b, engine_b) = fixture("b", 3);
    loom::model(move || {
        let reg = Arc::new(ModelRegistry::new());

        let fixtures = [(card_a.clone(), engine_a.clone()), (card_b.clone(), engine_b.clone())];
        let publishers: Vec<_> = fixtures
            .into_iter()
            .map(|(card, engine)| {
                let reg = Arc::clone(&reg);
                thread::spawn(move || reg.publish("k", card, engine).version)
            })
            .collect();

        let reader_reg = Arc::clone(&reg);
        let reader = thread::spawn(move || {
            let first = reader_reg.version_of("k");
            let second = reader_reg.version_of("k");
            (first, second)
        });

        let versions: Vec<u64> = publishers.into_iter().map(|h| h.join().unwrap()).collect();
        let (first, second) = reader.join().unwrap();

        assert_ne!(versions[0], versions[1], "racing publishes must draw distinct versions");
        assert!(
            first.unwrap_or(0) <= second.unwrap_or(0),
            "live version regressed between reads: {first:?} -> {second:?}"
        );
        let last = versions[0].max(versions[1]);
        assert_eq!(
            reg.version_of("k"),
            Some(last),
            "after both publishes the key must serve the higher version"
        );
        assert_eq!(reg.latest_version(), last);
    });
}

/// A reader races one publish: `current` returns either nothing (the
/// publish has not landed) or the *complete* installed artifact —
/// version, card id, and blob all from the same publish, never a torn
/// mix. Exercises the claim that the `RwLock` write critical section,
/// not the version counter's ordering, publishes the deployment.
#[test]
fn loom_registry_current_is_never_torn() {
    let (card, engine) = fixture("only", 2);
    let blob_len = card.blob.len();
    loom::model(move || {
        let reg = Arc::new(ModelRegistry::new());

        let publisher_reg = Arc::clone(&reg);
        let (pcard, pengine) = (card.clone(), engine.clone());
        let publisher = thread::spawn(move || publisher_reg.publish("k", pcard, pengine).version);

        let reader_reg = Arc::clone(&reg);
        let reader = thread::spawn(move || {
            reader_reg.current("k").map(|dep| {
                // Every field must come from the one completed publish.
                (dep.version, dep.card.id.clone(), dep.blob().len())
            })
        });

        let published = publisher.join().unwrap();
        if let Some((version, id, len)) = reader.join().unwrap() {
            assert_eq!(version, published, "reader saw a version no publish installed");
            assert_eq!(id, "only");
            assert_eq!(len, blob_len, "deployment observed with a torn blob");
        }
    });
}
