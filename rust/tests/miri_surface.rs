//! Fast, allocation-light tests sized for `cargo miri test`.
//!
//! Miri interprets every load and store, so the heavy property sweeps
//! and model-training integration tests are tagged out of its runs
//! (`#[cfg_attr(miri, ignore)]` / file-level `#![cfg(not(miri))]`).
//! This file is the surface that *stays in*: hand-built fixtures over
//! the crate's pointer-adjacent machinery — bit-level I/O, the
//! width-punning `BinMatrix` arena, and the scalar twins of the SIMD
//! kernels (under Miri `Tier::detect()` reports `Scalar`, so these are
//! exactly the paths a Miri run executes end to end). Everything here
//! also runs natively as a cheap smoke layer.

use toad::bitio::{bits_for, BitReader, BitWriter};
use toad::data::{BinColumns, BinMatrix};
use toad::simd::{
    accumulate_dense, accumulate_gathered, count_lt, descend_complete, descend_complete_gather,
    descend_oblivious, descend_oblivious_gather, descend_oblivious_row, descend_row, Tier,
};

#[test]
fn miri_reports_the_scalar_tier() {
    // Under Miri the dispatcher must never select a vector tier; the
    // scalar twins are bit-identical, so nothing else changes.
    #[cfg(miri)]
    assert_eq!(toad::simd::tier(), Tier::Scalar);
    // Natively: whatever was detected must be able to run.
    assert!(toad::simd::available_tiers().contains(&toad::simd::tier()));
}

#[test]
fn bitio_mixed_width_roundtrip() {
    // Widths 0..=64 with values at the width boundary, crossing byte
    // and 57-bit fast-path windows; the reader must reproduce every
    // masked value in order.
    let cases: Vec<(u64, u32)> = vec![
        (0, 0),
        (1, 1),
        (0b101, 3),
        (0xFF, 8),
        (0x1FF, 9),
        (0xABCD, 16),
        (0xDEAD_BEEF, 32),
        (0x0123_4567_89AB_CDEF, 57),
        (u64::MAX, 64),
        (u64::MAX, 7), // masked to 7 bits on write
        (42, 64),
    ];
    let mut w = BitWriter::new();
    for &(v, width) in &cases {
        w.write(v, width);
    }
    let bytes = w.into_bytes();
    let mut r = BitReader::new(&bytes);
    for &(v, width) in &cases {
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        assert_eq!(r.read(width), v & mask, "width {width}");
    }
}

#[test]
fn bitio_float_and_alignment_roundtrip() {
    let mut w = BitWriter::new();
    w.write(0b11, 2); // misalign on purpose
    w.write_f32(3.5);
    w.write_f16(-0.125);
    w.align_byte();
    w.write(0x77, 8);
    let bytes = w.into_bytes();

    let mut r = BitReader::new(&bytes);
    assert_eq!(r.read(2), 0b11);
    assert_eq!(r.read_f32(), 3.5);
    assert_eq!(r.read_f16(), -0.125); // exactly representable in f16
    r.align_byte();
    assert_eq!(r.read(8), 0x77);
}

#[test]
fn bitio_seek_rereads_any_field() {
    let mut w = BitWriter::new();
    w.write(0x5, 4);
    w.write(0x123, 12);
    w.write(0x9, 5);
    let bytes = w.into_bytes();

    let mut r = BitReader::new(&bytes);
    assert_eq!(r.read(4), 0x5);
    let mid = r.bit_pos();
    assert_eq!(r.read(12), 0x123);
    assert_eq!(r.read(5), 0x9);
    r.seek(mid);
    assert_eq!(r.read(12), 0x123, "seek must rewind to a mid-stream field");
    r.seek(0);
    assert_eq!(r.read(4), 0x5);
}

#[test]
fn bits_for_covers_the_small_range() {
    assert_eq!(bits_for(0), 0);
    assert_eq!(bits_for(1), 0);
    assert_eq!(bits_for(2), 1);
    assert_eq!(bits_for(3), 2);
    assert_eq!(bits_for(256), 8);
    assert_eq!(bits_for(257), 9);
}

#[test]
fn binmatrix_picks_u8_arena_and_mirrors_row_major() {
    // 3 rows × 2 features, all bin counts ≤ 256 → u8 arena.
    let m = BinMatrix::from_u16_columns(vec![vec![0, 3, 1], vec![2, 0, 2]]);
    assert!(m.is_u8());
    assert_eq!((m.n_rows(), m.n_features()), (3, 2));
    assert_eq!(m.bins_per_feature(), &[4, 3]);
    match m.columns() {
        BinColumns::U8(arena) => assert_eq!(arena, &[0, 3, 1, 2, 0, 2]),
        BinColumns::U16(_) => panic!("small-bin matrix must use the u8 arena"),
    }
    assert_eq!(m.bin(0, 1), 3);
    assert_eq!(m.bin(1, 2), 2);
    // Row-major mirror: row i is [f0, f1].
    assert_eq!(m.to_row_major(), vec![0, 2, 3, 0, 1, 2]);
}

#[test]
fn binmatrix_widens_to_u16_when_any_feature_overflows_u8() {
    // Feature 1 holds a code of 300 → 301 bins → whole arena is u16.
    let m = BinMatrix::from_u16_columns(vec![vec![0, 1], vec![300, 2]]);
    assert!(!m.is_u8());
    match m.columns() {
        BinColumns::U16(arena) => assert_eq!(arena, &[0, 1, 300, 2]),
        BinColumns::U8(_) => panic!("wide-bin matrix must use the u16 arena"),
    }
    assert_eq!(m.to_u16_columns(), vec![vec![0, 1], vec![300, 2]]);
}

#[test]
fn scalar_descent_walks_a_hand_built_tree() {
    // Depth-2 complete tree, 2 features:
    //        [f0 ≤ 5]
    //       /        \
    //   [f1 ≤ 2]   [f1 ≤ 7]
    // Leaves left→right: 0..4.
    let feat = [0u16, 1, 1];
    let thr = [5u16, 2, 7];
    // (f0, f1) → expected leaf.
    let rows: [([u16; 2], usize); 4] =
        [([3, 1], 0), ([3, 9], 1), ([9, 7], 2), ([9, 8], 3)];
    for (row, leaf) in rows {
        assert_eq!(descend_row(&feat, &thr, &row), leaf, "row {row:?}");
    }

    // The block kernel (scalar tier) must agree, including on a block
    // longer than one 8-lane group so the unrolled body runs.
    let mut xb = Vec::new();
    let mut want = Vec::new();
    for i in 0..19u16 {
        let r = [i % 11, (i * 3) % 11];
        want.push(descend_row(&feat, &thr, &r) as u32);
        xb.extend_from_slice(&r);
    }
    let mut out = vec![0u32; 19];
    descend_complete(Tier::Scalar, &feat, &thr, 2, &xb, 2, &mut out);
    assert_eq!(out, want);

    // The gather twin over a shuffled, repeating row subset.
    let lane_rows: Vec<u32> = vec![4, 0, 18, 7, 7, 12, 3, 9, 1, 16];
    let want_gather: Vec<u32> = lane_rows.iter().map(|&r| want[r as usize]).collect();
    let mut got = vec![0u32; lane_rows.len()];
    descend_complete_gather(Tier::Scalar, &feat, &thr, 2, &xb, 2, &lane_rows, &mut got);
    assert_eq!(got, want_gather);
}

#[test]
fn scalar_oblivious_descent_walks_a_hand_built_level_table() {
    // Depth-3 oblivious tree: every node on level ℓ shares feat[ℓ]/thr[ℓ].
    // Root-first, so the level-0 outcome is the leaf index's MSB:
    // idx = 4·(f0 > 5) + 2·(f1 > 2) + (f0 > 9).
    let feat = [0u16, 1, 0];
    let thr = [5u16, 2, 9];
    let leaf = |r: &[u16; 2]| -> usize {
        (usize::from(r[0] > 5) << 2) | (usize::from(r[1] > 2) << 1) | usize::from(r[0] > 9)
    };
    let rows: [[u16; 2]; 6] = [[3, 1], [3, 9], [9, 7], [12, 0], [12, 8], [6, 2]];
    for r in &rows {
        assert_eq!(descend_oblivious_row(&feat, &thr, r), leaf(r), "row {r:?}");
    }
    // The NaN sentinel bin (u16::MAX) must route right at every level
    // that reads it, exactly like `!(x ≤ t)` on floats.
    assert_eq!(descend_oblivious_row(&feat, &thr, &[u16::MAX, 0]), 0b101);
    assert_eq!(descend_oblivious_row(&feat, &thr, &[0, u16::MAX]), 0b010);

    // The block kernel (scalar tier) must agree on a block longer than
    // one 8-lane group so the unrolled body runs, tail included.
    let mut xb = Vec::new();
    let mut want = Vec::new();
    for i in 0..19u16 {
        let r = [i % 13, (i * 3) % 13];
        want.push(descend_oblivious_row(&feat, &thr, &r) as u32);
        xb.extend_from_slice(&r);
    }
    let mut out = vec![0u32; 19];
    descend_oblivious(Tier::Scalar, &feat, &thr, &xb, 2, &mut out);
    assert_eq!(out, want);

    // The gather twin over a shuffled, repeating row subset.
    let lane_rows: Vec<u32> = vec![4, 0, 18, 7, 7, 12, 3, 9, 1, 16];
    let want_gather: Vec<u32> = lane_rows.iter().map(|&r| want[r as usize]).collect();
    let mut got = vec![0u32; lane_rows.len()];
    descend_oblivious_gather(Tier::Scalar, &feat, &thr, &xb, 2, &lane_rows, &mut got);
    assert_eq!(got, want_gather);
}

#[test]
fn scalar_count_lt_is_partition_point() {
    let table = [-2.0f32, -0.5, 0.0, 0.5, 0.5, 3.25];
    for v in [-3.0f32, -2.0, -0.25, 0.0, 0.5, 0.75, 4.0, f32::NAN] {
        assert_eq!(
            count_lt(Tier::Scalar, &table, v),
            table.partition_point(|&b| b < v),
            "probe {v}"
        );
    }
    assert_eq!(count_lt(Tier::Scalar, &[], 1.0), 0);
}

#[test]
fn scalar_histogram_accumulation_matches_hand_totals() {
    // 6 rows into 3 bins at offset 1; triples are [grad, hess, count].
    let col: [u8; 6] = [0, 2, 1, 2, 0, 1];
    let grad = [1.0f64, 2.0, 4.0, 8.0, 16.0, 32.0];
    let hess = [0.5f64; 6];
    let mut data = vec![0.0f64; 3 * (3 + 1)];
    accumulate_dense(Tier::Scalar, &mut data, 1, &col, &grad, &hess);
    // bin 0 ← rows {0, 4}, bin 1 ← rows {2, 5}, bin 2 ← rows {1, 3}.
    assert_eq!(&data[3..6], &[17.0, 1.0, 2.0]);
    assert_eq!(&data[6..9], &[36.0, 1.0, 2.0]);
    assert_eq!(&data[9..12], &[10.0, 1.0, 2.0]);
    assert!(data[..3].iter().all(|&v| v == 0.0), "offset 0 must stay untouched");

    // Gathered twin over the subset {1, 3, 5} (u16 codes this time).
    let col16: [u16; 6] = [0, 2, 1, 2, 0, 1];
    let rows = [1u32, 3, 5];
    let og = [2.0f64, 8.0, 32.0];
    let oh = [0.5f64; 3];
    let mut data = vec![0.0f64; 3 * (3 + 1)];
    accumulate_gathered(Tier::Scalar, &mut data, 1, &col16, &rows, &og, &oh);
    assert_eq!(&data[6..9], &[32.0, 0.5, 1.0]); // bin 1 ← row 5
    assert_eq!(&data[9..12], &[10.0, 1.0, 2.0]); // bin 2 ← rows 1, 3
    assert_eq!(&data[3..6], &[0.0, 0.0, 0.0]); // bin 0: no subset row
}
