//! Bit-parity suite for the out-of-core trainer and the row-sharded
//! histogram reduction.
//!
//! The claims under test (see `gbdt::distributed` and
//! `data::binmatrix` module docs):
//!
//! * chunked (on-disk arena) training produces the **same model bits**
//!   as in-RAM training, for every block size and both code widths —
//!   the streamed histogram accumulation and partition perform the
//!   same f64 adds in the same order;
//! * row-sharded training is bit-identical for **every** worker count
//!   `K ≥ 1` (the reduction grid is fixed, never derived from `K`),
//!   over both stores, including ragged shards and empty grid cells;
//! * `HistogramSet::merge` is an exact sum — pinned against the scalar
//!   oracle on integer statistics, where f64 addition is associative;
//! * on integer-exact statistics the row-sharded fold coincides bit-
//!   for-bit with the plain (`row_workers = 0`) path;
//! * a malformed arena file is a clean `Err` from
//!   `ChunkedBinMatrix::open` — never a panic or a header-sized
//!   allocation (these tests are Miri-runnable; training tests are
//!   not, and are compiled out under Miri).

use toad::data::synth::synth_rows;
use toad::data::{ChunkedBinMatrix, Dataset, Task};
use toad::gbdt::{GbdtModel, Node};

fn arena_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("toad-parity-{}-{tag}.bin", std::process::id()))
}

/// Exact structural bits of a model: every tree node's discriminant and
/// payload with floats as raw bits, plus the base scores. Two models
/// compare equal here iff training made identical decisions *and*
/// identical arithmetic.
fn model_bits(m: &GbdtModel) -> Vec<u64> {
    let mut out: Vec<u64> = m.base_scores.iter().map(|b| b.to_bits()).collect();
    for stream in &m.trees {
        out.push(stream.len() as u64);
        for tree in stream {
            out.push(tree.nodes.len() as u64);
            for node in &tree.nodes {
                match *node {
                    Node::Internal { feature, bin, threshold, left, right } => {
                        out.push(0);
                        out.push(feature as u64);
                        out.push(bin as u64);
                        out.push(threshold.to_bits() as u64);
                        out.push(left as u64);
                        out.push(right as u64);
                    }
                    Node::Leaf { value } => {
                        out.push(1);
                        out.push(value.to_bits());
                    }
                }
            }
        }
    }
    out
}

fn synth_dataset(seed: u64, n: usize) -> Dataset {
    let (features, targets) = synth_rows(seed, 0..n);
    Dataset { name: "synth_rows".into(), features, targets, labels: vec![], task: Task::Regression }
}

#[cfg(not(miri))]
mod training {
    use super::*;
    use toad::data::binning::Binner;
    use toad::data::{BinMatrix, BinSource};
    use toad::gbdt::booster::{train, train_chunked, GbdtParams};
    use toad::gbdt::distributed::train_row_sharded;
    use toad::gbdt::histogram::{HistogramPool, HistogramSet, SHARD_MIN_ROWS};

    fn params(max_bins: usize) -> GbdtParams {
        GbdtParams { max_bins, ..GbdtParams::paper(3, 3) }
    }

    fn train_via_disk(ds: &Dataset, p: GbdtParams, block: usize, tag: &str) -> GbdtModel {
        let path = arena_path(tag);
        let n = ds.n_rows();
        let (binner, chunked) = Binner::fit_transform_to_disk(
            &path,
            n,
            ds.n_features(),
            p.max_bins,
            block,
            |range| {
                ds.features.iter().map(|col| col[range.clone()].to_vec()).collect::<Vec<Vec<f32>>>()
            },
        )
        .expect("streaming fit/transform");
        let model =
            train_chunked(binner, chunked, ds.targets.clone(), vec![], ds.task, &ds.name, p);
        let _ = std::fs::remove_file(&path);
        model
    }

    /// Tentpole claim, axis 1: chunked ≡ in-RAM, bit for bit, for every
    /// block size — including block 1, a ragged 63, an aligned 64, and
    /// a block larger than the dataset — over both code widths
    /// (max_bins 255 → u8 arena, 400 → u16).
    #[test]
    fn chunked_training_is_bit_identical_to_ram() {
        let n = 3000;
        let ds = synth_dataset(11, n);
        for max_bins in [255usize, 400] {
            let p = params(max_bins);
            let want = model_bits(&train(&ds, p));
            for block in [1usize, 63, 64, 4096, n + 1] {
                let tag = format!("{max_bins}-{block}");
                let got = model_bits(&train_via_disk(&ds, p, block, &tag));
                assert_eq!(want, got, "max_bins={max_bins} block={block}");
            }
        }
    }

    /// The two memory axes compose: chunked + row-sharded ≡ in-RAM +
    /// row-sharded, bit for bit, at any block size and worker count.
    #[test]
    fn chunked_and_row_sharding_compose_bit_identically() {
        let n = 6000;
        let ds = synth_dataset(13, n);
        let p = GbdtParams { row_workers: 1, ..params(255) };
        let want = model_bits(&train(&ds, p));
        for (block, workers) in [(997usize, 2usize), (4096, 7)] {
            let pw = GbdtParams { row_workers: workers, ..p };
            let tag = format!("rs-{block}-{workers}");
            let got = model_bits(&train_via_disk(&ds, pw, block, &tag));
            assert_eq!(want, got, "block={block} workers={workers}");
        }
    }

    /// Tentpole claim, axis 2: every worker count K ≥ 1 trains the same
    /// model bits ("single-node" is K = 1), on row counts chosen so the
    /// fixed grid has ragged cells.
    #[test]
    fn row_sharded_is_bit_identical_across_worker_counts() {
        for n in [6000usize, 6001] {
            let ds = synth_dataset(17, n);
            let p = params(255);
            let want = model_bits(&train_row_sharded(&ds, p, 1));
            for workers in [2usize, 3, 7] {
                let got = model_bits(&train_row_sharded(&ds, p, workers));
                assert_eq!(want, got, "n={n} workers={workers}");
            }
        }
    }

    fn hist_bits(h: &HistogramSet, bins: &[usize]) -> Vec<u64> {
        let mut out = Vec::new();
        for (f, &nb) in bins.iter().enumerate() {
            for b in 0..nb {
                let (g, h_, c) = h.bin(f, b);
                out.extend([g.to_bits(), h_.to_bits(), c as u64]);
            }
        }
        out
    }

    /// Direct pool-level check with *empty grid cells*: a leaf whose
    /// rows occupy only the first and last of the 8 fixed cells reduces
    /// to the same bits for every worker count (empty cells are skipped
    /// by data, not by schedule).
    #[test]
    fn row_sharded_build_handles_empty_cells_identically() {
        let n = 16 * 1024;
        let ds = synth_dataset(19, n);
        let binner = Binner::fit(&ds, 255);
        let binned = binner.bin_matrix(&ds);
        let bins: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
        let grad: Vec<f64> = ds.targets.clone();
        let hess = vec![1.0; n];
        // ≥ SHARD_MIN_ROWS rows, but cells 1..7 of the fixed 8-cell grid
        // are empty (cell width is n/8 = 2048).
        let rows: Vec<u32> = (0..2048u32).chain((n as u32 - 2048)..n as u32).collect();
        assert!(rows.len() >= SHARD_MIN_ROWS);
        let mut want: Option<Vec<u64>> = None;
        for workers in [1usize, 2, 3, 7, 8] {
            let mut pool = HistogramPool::new(&bins);
            pool.set_row_sharding(n, workers);
            let h = pool.build_source(BinSource::Ram(&binned), &rows, &grad, &hess);
            let got = hist_bits(&h, &bins);
            match &want {
                None => want = Some(got),
                Some(w) => assert_eq!(w, &got, "workers={workers}"),
            }
        }
    }

    /// `merge` is an exact bin-for-bin sum: on integer statistics
    /// (where f64 addition is associative) merging two disjoint
    /// partials equals the scalar oracle on the union, bit for bit.
    #[test]
    fn merge_matches_scalar_oracle_on_union() {
        let cols: Vec<Vec<u16>> = vec![
            (0..600).map(|i| (i % 5) as u16).collect(),
            (0..600).map(|i| (i % 3) as u16).collect(),
        ];
        let binned = BinMatrix::from_u16_columns(cols);
        let grad: Vec<f64> = (0..600).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let hess = vec![1.0; 600];
        let union: Vec<u32> = (0..600).collect();
        let (a_rows, b_rows): (Vec<u32>, Vec<u32>) = (0..600u32).partition(|&i| i % 7 < 3);
        let bins = [5usize, 3];
        let mut a = HistogramSet::new(&bins);
        a.build(&binned, &a_rows, &grad, &hess);
        let mut b = HistogramSet::new(&bins);
        b.build(&binned, &b_rows, &grad, &hess);
        a.merge(&b);
        let mut oracle = HistogramSet::new(&bins);
        oracle.build_scalar(&binned, &union, &grad, &hess);
        assert_eq!(hist_bits(&a, &bins), hist_bits(&oracle, &bins));
    }

    /// On integer-exact statistics the banded fold and the historical
    /// ungrouped fold compute identical sums, so round 1 of row-sharded
    /// training coincides bit-for-bit with `row_workers = 0`. (On
    /// general data they differ in the last ulp — that is expected and
    /// why `row_workers = 0` stays the default.)
    #[test]
    fn row_sharded_round_one_matches_plain_on_integer_stats() {
        let n = SHARD_MIN_ROWS;
        // Balanced ±1 targets (base score exactly 0.0 ⇒ grads are ±1,
        // hessians 1), split-learnable from two small-integer features.
        let f0: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let f1: Vec<f32> = (0..n).map(|i| ((i / 2) % 4) as f32).collect();
        let targets: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let ds = Dataset {
            name: "int_exact".into(),
            features: vec![f0, f1],
            targets,
            labels: vec![],
            task: Task::Regression,
        };
        let p = GbdtParams { max_bins: 16, ..GbdtParams::paper(1, 2) };
        let plain = model_bits(&train(&ds, p));
        for workers in [1usize, 3] {
            let sharded = model_bits(&train_row_sharded(&ds, p, workers));
            assert_eq!(plain, sharded, "workers={workers}");
        }
    }

    /// Streaming two-pass fit reproduces `Binner::fit` boundaries bit
    /// for bit, including NaNs (skipped), heavy duplicates, and the
    /// `-0.0`/`0.0` merge.
    #[test]
    fn streaming_fit_boundaries_match_in_ram_fit() {
        let n = 997;
        let mut col0: Vec<f32> = (0..n)
            .map(|i| match i % 7 {
                0 => f32::NAN,
                1 => -0.0,
                2 => 0.0,
                k => (k as f32) * 0.25 - 0.5,
            })
            .collect();
        col0[500] = -1.5e30;
        col0[501] = 1.5e30;
        let col1: Vec<f32> = (0..n).map(|i| ((i * 37) % 101) as f32 / 7.0).collect();
        let ds = Dataset {
            name: "fitcheck".into(),
            features: vec![col0, col1],
            targets: vec![0.0; n],
            labels: vec![],
            task: Task::Regression,
        };
        for max_bins in [8usize, 64, 255] {
            let want = Binner::fit(&ds, max_bins);
            let path = arena_path(&format!("fit-{max_bins}"));
            let (got, _chunked) = Binner::fit_transform_to_disk(
                &path,
                n,
                ds.n_features(),
                max_bins,
                64,
                |range| {
                    ds.features
                        .iter()
                        .map(|col| col[range.clone()].to_vec())
                        .collect::<Vec<Vec<f32>>>()
                },
            )
            .expect("streaming fit");
            let _ = std::fs::remove_file(&path);
            for f in 0..ds.n_features() {
                assert_eq!(want.n_bins(f), got.n_bins(f), "max_bins={max_bins} f={f}");
                for b in 0..want.n_bins(f).saturating_sub(1) {
                    assert_eq!(
                        want.threshold_value(f, b).to_bits(),
                        got.threshold_value(f, b).to_bits(),
                        "max_bins={max_bins} f={f} boundary {b}"
                    );
                }
            }
        }
    }

    /// Chunk loads rehydrate exactly the columns `bin_matrix` produces,
    /// at both code widths (positional reads; not Miri-runnable).
    #[test]
    fn arena_roundtrip_matches_resident_matrix() {
        let n = 333;
        let ds = synth_dataset(23, n);
        for max_bins in [255usize, 400] {
            let binner = Binner::fit(&ds, max_bins);
            let want = binner.bin_matrix(&ds);
            let path = arena_path(&format!("rt-{max_bins}"));
            let (_b2, chunked) = Binner::fit_transform_to_disk(
                &path,
                n,
                ds.n_features(),
                max_bins,
                50,
                |range| {
                    ds.features
                        .iter()
                        .map(|col| col[range.clone()].to_vec())
                        .collect::<Vec<Vec<f32>>>()
                },
            )
            .expect("streaming fit");
            assert_eq!(chunked.is_u8(), want.is_u8(), "width parity (max_bins={max_bins})");
            assert_eq!(chunked.n_chunks(), n.div_ceil(50));
            for c in 0..chunked.n_chunks() {
                let range = chunked.chunk_range(c);
                let chunk = chunked.load_chunk(c);
                for f in 0..want.n_features() {
                    for (i, row) in range.clone().enumerate() {
                        assert_eq!(chunk.bin(f, i), want.bin(f, row), "chunk {c} f={f} row {row}");
                    }
                }
            }
            let _ = std::fs::remove_file(&path);
        }
    }
}

// ---------------------------------------------------------------------
// Malformed-arena robustness (Miri-runnable: `open` uses sequential
// reads only and never allocates from an unvouched header).
// ---------------------------------------------------------------------

/// A syntactically valid little header: magic, width 1, n_rows 4,
/// chunk_rows 2, 2 features of 4 bins each, and the 8 body bytes.
fn valid_arena_bytes() -> Vec<u8> {
    let mut v = Vec::new();
    v.extend_from_slice(b"TOADBIN1");
    v.push(1); // width
    v.extend_from_slice(&4u64.to_le_bytes()); // n_rows
    v.extend_from_slice(&2u64.to_le_bytes()); // chunk_rows
    v.extend_from_slice(&2u32.to_le_bytes()); // n_features
    v.extend_from_slice(&4u32.to_le_bytes()); // bins f0
    v.extend_from_slice(&4u32.to_le_bytes()); // bins f1
    v.extend_from_slice(&[0, 1, 2, 3, 3, 2, 1, 0]); // body: 4 rows × 2 features
    v
}

fn open_bytes(tag: &str, bytes: &[u8]) -> Result<ChunkedBinMatrix, toad::error::Error> {
    let path = arena_path(tag);
    std::fs::write(&path, bytes).expect("write test arena");
    let r = ChunkedBinMatrix::open(&path);
    let _ = std::fs::remove_file(&path);
    r
}

#[test]
fn well_formed_header_opens() {
    let m = open_bytes("ok", &valid_arena_bytes()).expect("valid arena must open");
    assert_eq!(m.n_rows(), 4);
    assert_eq!(m.n_features(), 2);
    assert_eq!(m.chunk_rows(), 2);
    assert!(m.is_u8());
}

#[test]
fn truncated_prefix_is_err() {
    for len in [0usize, 7, 28] {
        assert!(open_bytes("trunc", &valid_arena_bytes()[..len]).is_err(), "len {len}");
    }
}

#[test]
fn bad_magic_is_err() {
    let mut v = valid_arena_bytes();
    v[0] ^= 0x20;
    assert!(open_bytes("magic", &v).is_err());
}

#[test]
fn bad_width_is_err() {
    for w in [0u8, 3, 255] {
        let mut v = valid_arena_bytes();
        v[8] = w;
        assert!(open_bytes("width", &v).is_err(), "width {w}");
    }
}

#[test]
fn zero_chunk_rows_is_err() {
    let mut v = valid_arena_bytes();
    v[17..25].copy_from_slice(&0u64.to_le_bytes());
    assert!(open_bytes("chunk0", &v).is_err());
}

#[test]
fn width_bin_contradiction_is_err() {
    // Width 2 but every bin count fits u8: `from_fn` would have chosen
    // width 1, so loaded chunks could not match the resident arena.
    let mut v = valid_arena_bytes();
    v[8] = 2;
    v.extend_from_slice(&[0u8; 8]); // body grows to 4 rows × 2 features × 2 bytes
    assert!(open_bytes("contradict", &v).is_err());
}

#[test]
fn size_mismatch_is_err() {
    let mut v = valid_arena_bytes();
    v.push(0); // one trailing byte
    assert!(open_bytes("long", &v).is_err());
    let mut v = valid_arena_bytes();
    v.truncate(v.len() - 1); // one missing body byte
    assert!(open_bytes("short", &v).is_err());
}

#[test]
fn zero_bin_feature_is_err() {
    let mut v = valid_arena_bytes();
    v[29..33].copy_from_slice(&0u32.to_le_bytes());
    assert!(open_bytes("zerobin", &v).is_err());
}

#[test]
fn hostile_dimensions_do_not_allocate() {
    // Claims ~16M features / huge rows in a tiny file: must be a clean
    // Err (the length check precedes any header-sized allocation), not
    // an OOM or capacity panic.
    let mut v = valid_arena_bytes();
    v[25..29].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(open_bytes("bigf", &v).is_err());
    let mut v = valid_arena_bytes();
    v[9..17].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(open_bytes("bigrows", &v).is_err());
    // Overflow bait: n_rows × n_features × width wraps u64.
    let mut v = valid_arena_bytes();
    v[9..17].copy_from_slice(&(u64::MAX / 2).to_le_bytes());
    v[25..29].copy_from_slice(&8u32.to_le_bytes());
    assert!(open_bytes("overflow", &v).is_err());
}
