//! Boundary-width round-trips for the ToaD layout.
//!
//! The layout squeezes every field to a minimal bit width, so the
//! interesting inputs are the ones that land exactly on a width
//! boundary: depth-15 trees (the 4-bit depth field's maximum),
//! single-leaf trees (zero-width references), features with exactly 256
//! thresholds (ranks fill 8 bits; floored integer thresholds fill the
//! u8 value width), and NaN probe rows (which must route right at every
//! split in every engine). Each case goes encode → validate → decode →
//! predict and through [`PackedModel`]'s direct bit-level execution.

// Everything below trains real models, spawns threads, or sweeps large
// inputs - orders of magnitude too slow under the Miri interpreter.
// `tests/miri_surface.rs` holds the fast coverage that stays in Miri runs.
#![cfg(not(miri))]

use toad::gbdt::loss::Objective;
use toad::gbdt::tree::{Node, Tree};
use toad::gbdt::GbdtModel;
use toad::layout::{decode, encode, toad_format, EncodeOptions, FeatureInfo, PackedModel};
use toad::prng::Pcg64;
use toad::testutil::prop::run_prop;

fn wrap(trees: Vec<Tree>, n_features: usize) -> GbdtModel {
    GbdtModel {
        objective: Objective::L2,
        base_scores: vec![0.5],
        trees: vec![trees],
        n_features,
        name: "roundtrip-test".into(),
    }
}

/// Exact-threshold encode options (no lossy f16).
fn exact() -> EncodeOptions {
    EncodeOptions { allow_f16: false, ..Default::default() }
}

/// Assert pointer / decoded / packed predictions agree exactly on
/// `probes` (leaf values in these tests are integers, exactly
/// representable in the layout's f32 leaf table).
fn assert_roundtrip_parity(model: &GbdtModel, finfo: &[FeatureInfo], probes: &[Vec<f32>]) {
    let blob = encode(model, finfo, &exact()).expect("model fits every layout field");
    toad_format::validate_blob(&blob).expect("encoded blob must validate");
    let decoded = decode(&blob);
    let packed = PackedModel::from_bytes(blob);
    for (i, x) in probes.iter().enumerate() {
        let want = model.predict_raw(x);
        assert_eq!(decoded.predict_raw(x), want, "probe {i}: decoded vs pointer");
        assert_eq!(packed.predict_raw(x), want, "probe {i}: packed vs pointer");
    }
}

/// A left-leaning chain of `len` internal nodes (tree depth == `len`),
/// with distinct integer-representable thresholds and integer leaves.
fn chain_tree(len: usize) -> Tree {
    let mut nodes = Vec::new();
    for d in 0..len {
        let idx = nodes.len();
        nodes.push(Node::Internal {
            feature: 0,
            bin: d as u16,
            threshold: d as f32 + 0.5,
            left: idx + 2,
            right: idx + 1,
        });
        nodes.push(Node::Leaf { value: d as f64 + 1.0 });
    }
    nodes.push(Node::Leaf { value: -1.0 });
    Tree { nodes }
}

#[test]
fn depth_15_tree_roundtrips_at_the_depth_field_maximum() {
    // Depth 15 is the largest value the 4-bit depth field can hold;
    // its complete form has 2^15 leaf slots, all replicated from 16
    // real leaves.
    let model = wrap(vec![chain_tree(15)], 1);
    let finfo = [FeatureInfo::generic_float()];
    let probes: Vec<Vec<f32>> = (0..=16)
        .map(|i| vec![i as f32])
        .chain([vec![-5.0], vec![7.25], vec![f32::NAN]])
        .collect();
    assert_roundtrip_parity(&model, &finfo, &probes);
}

#[test]
fn single_leaf_trees_roundtrip_with_zero_width_references() {
    // Bare-leaf ensembles have no used features, no thresholds, and
    // (with one distinct value) zero-bit leaf references.
    let same = wrap(vec![Tree::leaf(2.0); 3], 2);
    let mixed = wrap(vec![Tree::leaf(2.0), Tree::leaf(-3.0), Tree::leaf(2.0)], 2);
    let probes = vec![vec![0.0, 0.0], vec![f32::NAN, f32::NAN]];
    assert_roundtrip_parity(&same, &[FeatureInfo::generic_float(); 2], &probes);
    assert_roundtrip_parity(&mixed, &[FeatureInfo::generic_float(); 2], &probes);
}

/// 256 stumps, each splitting feature 0 at a distinct threshold
/// `i + 0.5` — the per-feature threshold table holds exactly 256
/// entries, so ranks fill all 8 bits and `count − 1 == 255` fills the
/// map's count field.
fn stumps_256() -> GbdtModel {
    let trees: Vec<Tree> = (0..256)
        .map(|i| Tree {
            nodes: vec![
                Node::Internal {
                    feature: 0,
                    bin: i as u16,
                    threshold: i as f32 + 0.5,
                    left: 1,
                    right: 2,
                },
                Node::Leaf { value: i as f64 + 1.0 },
                Node::Leaf { value: -(i as f64 + 1.0) },
            ],
        })
        .collect();
    wrap(trees, 1)
}

#[test]
fn exactly_256_thresholds_roundtrip_as_floats() {
    let model = stumps_256();
    let finfo = [FeatureInfo::generic_float()];
    let probes: Vec<Vec<f32>> = [-1.0f32, 0.7, 100.2, 255.4, 255.6, 300.0, f32::NAN]
        .iter()
        .map(|&x| vec![x])
        .collect();
    assert_roundtrip_parity(&model, &finfo, &probes);
}

#[test]
fn exactly_256_thresholds_roundtrip_as_u8_integers() {
    // With an integer-valued feature the thresholds floor to 0..=255,
    // exactly filling the 8-bit unsigned width (`max_floor == 255 <
    // 2^8`) — the boundary the width-selection logic must not
    // overshoot. Floored thresholds are routing-equivalent only on
    // integer inputs, so probes are integers (plus NaN).
    let model = stumps_256();
    let finfo = [FeatureInfo { is_integer: true, min: 0.0, max: 400.0 }];
    let probes: Vec<Vec<f32>> = [0.0f32, 1.0, 128.0, 255.0, 256.0, 400.0, f32::NAN]
        .iter()
        .map(|&x| vec![x])
        .collect();
    assert_roundtrip_parity(&model, &finfo, &probes);
}

/// Random tree drawing (feature, bin, threshold) from shared
/// per-feature tables so the encoder's bin → value map is consistent.
fn random_tree(rng: &mut Pcg64, tables: &[Vec<f32>], max_depth: usize) -> Tree {
    fn grow(
        rng: &mut Pcg64,
        tables: &[Vec<f32>],
        depth: usize,
        max_depth: usize,
        nodes: &mut Vec<Node>,
    ) -> usize {
        let idx = nodes.len();
        if depth >= max_depth || rng.gen_bool(0.3) {
            // Integer leaves: exactly representable as f32, so the
            // round trip is bit-exact.
            nodes.push(Node::Leaf { value: rng.gen_range(64) as f64 - 32.0 });
            return idx;
        }
        nodes.push(Node::Leaf { value: 0.0 }); // placeholder
        let feature = rng.gen_range(tables.len());
        let bin = rng.gen_range(tables[feature].len());
        let threshold = tables[feature][bin];
        let left = grow(rng, tables, depth + 1, max_depth, nodes);
        let right = grow(rng, tables, depth + 1, max_depth, nodes);
        nodes[idx] = Node::Internal { feature, bin: bin as u16, threshold, left, right };
        idx
    }
    let mut nodes = Vec::new();
    grow(rng, tables, 0, max_depth, &mut nodes);
    Tree { nodes }
}

#[test]
fn prop_random_models_roundtrip_with_nan_probes() {
    run_prop("packed layout roundtrip", 40, |g| {
        let d = g.usize_in(1, 5);
        let mut rng = Pcg64::new(g.case_seed ^ 0xA5);
        let tables: Vec<Vec<f32>> = (0..d)
            .map(|_| {
                let mut t: Vec<f32> = (0..1 + rng.gen_range(10))
                    .map(|_| rng.gen_uniform(-2.0, 2.0) as f32)
                    .collect();
                t.sort_by(f32::total_cmp);
                t.dedup();
                t
            })
            .collect();
        let n_trees = g.usize_in(1, 5);
        let trees: Vec<Tree> = (0..n_trees)
            .map(|_| random_tree(&mut rng, &tables, g.usize_in(0, 5)))
            .collect();
        let model = wrap(trees, d);
        let finfo = vec![FeatureInfo::generic_float(); d];
        let probes: Vec<Vec<f32>> = (0..24)
            .map(|_| {
                (0..d)
                    .map(|_| {
                        if g.bool(0.1) {
                            f32::NAN
                        } else {
                            g.f64_in(-2.5, 2.5) as f32
                        }
                    })
                    .collect()
            })
            .collect();
        assert_roundtrip_parity(&model, &finfo, &probes);
    });
}
