//! Integration: the XLA predict path must agree with native inference.
//!
//! Compiled only with the `xla` cargo feature (the PJRT bindings are an
//! optional external dependency; see Cargo.toml). Additionally requires
//! `make artifacts` to have run; tests skip (with a notice) when the
//! artifact directory is absent so `cargo test` stays green in a fresh
//! checkout.
#![cfg(feature = "xla")]

use toad::data::synth::PaperDataset;
use toad::data::train_test_split;
use toad::gbdt::{self, GbdtParams};
use toad::runtime::{tensorize, PredictEngine, XlaRuntime};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("MANIFEST.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime test: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn xla_predict_matches_native_binary() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::open(dir).unwrap();

    let data = PaperDataset::BreastCancer.generate(31);
    let (train_set, test_set) = train_test_split(&data, 0.2, 1);
    let model = gbdt::booster::train(&train_set, GbdtParams::paper(24, 3));

    let tm = tensorize(&model, 256, 4, 64, 1).unwrap();
    let mut engine = PredictEngine::new(&rt, tm, 256, 64).unwrap();

    let n = test_set.n_rows().min(256);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| test_set.row(i)).collect();
    let got = engine.predict(&rows).unwrap();
    for (i, row) in rows.iter().enumerate() {
        let want = model.predict_raw(row)[0];
        let have = got[i][0];
        assert!(
            (want - have).abs() < 1e-3 * want.abs().max(1.0),
            "row {i}: native {want} vs xla {have}"
        );
    }
}

#[test]
fn xla_predict_matches_native_multiclass() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::open(dir).unwrap();

    let data = PaperDataset::WineQuality.generate(32);
    let data = data.select(&(0..1200).collect::<Vec<_>>());
    let (train_set, test_set) = train_test_split(&data, 0.2, 2);
    // Wine has 7 classes; the o8 artifact serves up to 8 outputs, so
    // train a 7-output model and pad the output dimension... the
    // artifact is exact in `o`, so train with an 8th empty class
    // stream instead: we simply check the o8 artifact against a model
    // whose task has been embedded into 8 outputs.
    let model = gbdt::booster::train(&train_set, GbdtParams::paper(8, 3));
    assert_eq!(model.n_outputs(), 7);
    // Embed: add an empty 8th output stream (base −inf is unnecessary;
    // argmax over 7 real streams is preserved with base 0 trees absent
    // only if raw8 < max(raw0..6); use a very negative base).
    let mut model8 = model.clone();
    model8.trees.push(Vec::new());
    model8.base_scores.push(-1e9);

    let tm = tensorize(&model8, 256, 4, 64, 8).unwrap();
    let mut engine = PredictEngine::new(&rt, tm, 256, 64).unwrap();

    let n = test_set.n_rows().min(128);
    let rows: Vec<Vec<f32>> = (0..n).map(|i| test_set.row(i)).collect();
    let got = engine.predict(&rows).unwrap();
    let mut agree = 0usize;
    for (i, row) in rows.iter().enumerate() {
        let want = model.predict_class(row);
        let have = got[i]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if want == have {
            agree += 1;
        }
    }
    assert!(agree as f64 / n as f64 > 0.99, "class agreement {agree}/{n}");
}

#[test]
fn small_batches_are_padded() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::open(dir).unwrap();
    let data = PaperDataset::Kin8nm.generate(33).select(&(0..500).collect::<Vec<_>>());
    let model = gbdt::booster::train(&data, GbdtParams::paper(8, 2));
    let tm = tensorize(&model, 256, 4, 64, 1).unwrap();
    let mut engine = PredictEngine::new(&rt, tm, 32, 64).unwrap();

    // 3-row batch through a 32-batch artifact.
    let rows: Vec<Vec<f32>> = (0..3).map(|i| data.row(i)).collect();
    let got = engine.predict(&rows).unwrap();
    assert_eq!(got.len(), 3);
    for (i, row) in rows.iter().enumerate() {
        let want = model.predict_raw(row)[0];
        assert!((want - got[i][0]).abs() < 1e-3 * want.abs().max(1.0));
    }
}

#[test]
fn xla_histogram_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::open(dir).unwrap();
    let engine = toad::runtime::HistogramEngine::new(&rt, 4096, 64, 64).unwrap();

    // Random binned data within the artifact's bin budget.
    let data = PaperDataset::CovertypeBinary.generate(41);
    let data = data.select(&(0..3000).collect::<Vec<_>>());
    let binner = toad::data::Binner::fit(&data, 64);
    let binned = binner.bin_matrix(&data);
    let n = data.n_rows();
    let grad: Vec<f64> = (0..n).map(|i| ((i % 13) as f64 - 6.0) / 3.0).collect();
    let hess: Vec<f64> = (0..n).map(|i| 0.5 + ((i % 5) as f64) / 10.0).collect();

    let got = engine.run(&binned.to_u16_columns(), &grad, &hess).unwrap();

    // Native oracle.
    let bins_per: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
    let mut native = toad::gbdt::histogram::HistogramSet::new(&bins_per);
    let rows: Vec<u32> = (0..n as u32).collect();
    native.build(&binned, &rows, &grad, &hess);

    for f in 0..binner.n_features() {
        for b in 0..binner.n_bins(f) {
            let (g, h, _) = native.bin(f, b);
            let [xg, xh] = got[engine.index(f, b)];
            assert!(
                (g - xg).abs() < 1e-2 * g.abs().max(1.0),
                "feature {f} bin {b}: grad {g} vs xla {xg}"
            );
            assert!(
                (h - xh).abs() < 1e-2 * h.abs().max(1.0),
                "feature {f} bin {b}: hess {h} vs xla {xh}"
            );
        }
    }
}
