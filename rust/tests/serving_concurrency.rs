//! Concurrent serving: N writer threads publishing registry versions
//! while M reader threads hammer `FleetServer::submit` through `&self`.
//!
//! The contract under test (ISSUE 4 acceptance):
//! * every response comes from a version that was published — never a
//!   torn, partial, or never-published state (proven bit-exactly: each
//!   version's model predicts a distinct constant, and every reply
//!   must match its reported version's constant to the bit),
//! * a publish during live traffic changes the serving version without
//!   dropping, blocking, or corrupting in-flight requests,
//! * the bounded queue surfaces `SubmitError::Overloaded` backpressure
//!   instead of buffering without limit.

// Everything below trains real models, spawns threads, or sweeps large
// inputs - orders of magnitude too slow under the Miri interpreter.
// `tests/miri_surface.rs` holds the fast coverage that stays in Miri runs.
#![cfg(not(miri))]

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;
use toad::coordinator::batcher::SubmitError;
use toad::coordinator::{BatcherConfig, FleetServer, ModelCard};
use toad::data::{Dataset, Task};
use toad::gbdt::{self, GbdtModel, GbdtParams};

/// A model that predicts exactly `c` for every row: one depth-0 round
/// on a constant-target regression set leaves the base score = mean =
/// `c` and a zero leaf. The quantized serving engine is bit-identical
/// to `predict_raw`, so replies can be checked with `to_bits`.
fn constant_model(c: f64) -> (GbdtModel, f64) {
    let n = 32;
    let data = Dataset {
        name: format!("const_{c}"),
        features: (0..4).map(|f| (0..n).map(|i| (i * (f + 1)) as f32 * 0.1).collect()).collect(),
        targets: vec![c; n],
        labels: Vec::new(),
        task: Task::Regression,
    };
    let model = gbdt::booster::train(&data, GbdtParams::paper(1, 0));
    let expect = model.predict_raw(&data.row(0))[0];
    (model, expect)
}

fn card(id: &str, score: f64) -> ModelCard {
    ModelCard { id: id.into(), score, size_bytes: 1, blob: Vec::new() }
}

#[test]
fn hot_swap_under_concurrent_load() {
    let mut server = FleetServer::new();
    server.add_registry_gateway(
        "m",
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
            queue_depth: 4096,
            ..Default::default()
        },
    );

    // version → the exact constant that version predicts. The publish
    // and its map insert happen under the map lock, and readers look
    // replies up under the same lock — so by the time a reader can
    // look up a version it observed, the entry is already there.
    let expected: Mutex<HashMap<u64, u64>> = Mutex::new(HashMap::new());
    let publish = |v: usize| {
        let (model, c) = constant_model(v as f64);
        let engine = model.quantize();
        let mut map = expected.lock().unwrap();
        let dep = server.registry().publish("m", card(&format!("v{v}"), v as f64), engine);
        map.insert(dep.version, c.to_bits());
        dep.version
    };

    let v1 = publish(1);
    let row = vec![0.5f32; 4];
    // One synchronous request up front pins version 1 in the metrics,
    // so the final version-count assertion can demand ≥ 2 versions
    // without racing reader startup against the first swap.
    let warm = server.submit("m", row.clone()).unwrap().wait().unwrap();
    assert_eq!(warm.version, v1);

    let stop = AtomicBool::new(false);
    let served = AtomicUsize::new(0);

    std::thread::scope(|s| {
        // Writer: five more versions land while traffic is flowing.
        let writer = s.spawn(|| {
            for v in 2..=6 {
                std::thread::sleep(Duration::from_millis(3));
                publish(v);
            }
        });

        // Readers: hammer submit, verify every reply bit-exactly.
        for t in 0..4 {
            let server = &server;
            let expected = &expected;
            let stop = &stop;
            let served = &served;
            let row = &row;
            s.spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    let ticket = match server.submit("m", row.clone()) {
                        Ok(tk) => tk,
                        Err(SubmitError::Overloaded { .. }) => continue, // shed, retry
                        Err(e) => panic!("reader {t}: unexpected submit error {e}"),
                    };
                    let reply = ticket.wait().expect("published key must serve");
                    let want = *expected
                        .lock()
                        .unwrap()
                        .get(&reply.version)
                        .unwrap_or_else(|| panic!("version {} never published", reply.version));
                    assert_eq!(
                        reply.scores[0].to_bits(),
                        want,
                        "reader {t}: reply from version {} is torn",
                        reply.version
                    );
                    served.fetch_add(1, Ordering::Relaxed);
                }
            });
        }

        writer.join().expect("writer");
        // Let readers observe the final version, then stop them.
        std::thread::sleep(Duration::from_millis(20));
        stop.store(true, Ordering::Relaxed);
    });

    assert!(served.load(Ordering::Relaxed) > 0, "readers must have served requests");

    // After the last publish, a fresh request must be served by the
    // newest version (flushes resolve the registry at flush time).
    let final_reply = server.submit("m", row).unwrap().wait().unwrap();
    assert_eq!(final_reply.version, server.registry().latest_version());
    let vc = server.metrics("m").unwrap().version_counts();
    let published = expected.lock().unwrap();
    for &(v, _) in &vc {
        assert!(published.contains_key(&v), "metrics recorded unpublished version {v}");
    }
    assert!(vc.len() >= 2, "hot swap must have been observed across versions: {vc:?}");
}

#[test]
fn overload_backpressure_surfaces_and_recovers() {
    let mut server = FleetServer::new();
    server.add_registry_gateway(
        "m",
        BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_secs(1),
            queue_depth: 2,
            ..Default::default()
        },
    );
    let (model, expect) = constant_model(7.0);
    server.registry().publish("m", card("v", 0.9), model.quantize());

    // A tight submit loop outpaces the worker (enqueue is nanoseconds,
    // a flush runs a real batch), so the 2-deep bound must trip; every
    // admitted request must still be served with the exact payload.
    let row = vec![0.1f32; 4];
    let mut tickets = Vec::new();
    let mut shed = 0usize;
    for _ in 0..50_000 {
        match server.submit("m", row.clone()) {
            Ok(tk) => tickets.push(tk),
            Err(SubmitError::Overloaded { depth }) => {
                assert_eq!(depth, 2);
                shed += 1;
                if shed > 8 {
                    break;
                }
            }
            Err(e) => panic!("unexpected submit error {e}"),
        }
    }
    assert!(shed > 0, "bounded queue never pushed back under a tight submit loop");
    for tk in tickets {
        let reply = tk.wait().expect("admitted request must be served");
        assert_eq!(reply.scores[0].to_bits(), expect.to_bits());
    }
    // And the gateway keeps serving after the burst.
    assert!(server.predict("m", row).is_ok());
}

#[test]
fn retire_fails_clean_and_republish_recovers() {
    let mut server = FleetServer::new();
    server.add_registry_gateway(
        "m",
        BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_depth: 64,
            ..Default::default()
        },
    );
    let (m1, c1) = constant_model(1.0);
    let d1 = server.registry().publish("m", card("v1", 0.9), m1.quantize());
    let row = vec![0.2f32; 4];
    let r1 = server.submit("m", row.clone()).unwrap().wait().unwrap();
    assert_eq!((r1.version, r1.scores[0].to_bits()), (d1.version, c1.to_bits()));

    let retired = server.registry().retire("m").expect("was live");
    assert_eq!(retired.version, d1.version);
    // Submit is admitted (the route exists) but resolves to an error,
    // not a hang or a stale prediction.
    let err = server.submit("m", row.clone()).unwrap().wait();
    assert!(err.is_err(), "retired key must not serve");

    let (m2, c2) = constant_model(2.0);
    let d2 = server.registry().publish("m", card("v2", 0.9), m2.quantize());
    assert!(d2.version > d1.version);
    let r2 = server.submit("m", row).unwrap().wait().unwrap();
    assert_eq!((r2.version, r2.scores[0].to_bits()), (d2.version, c2.to_bits()));
}

#[test]
fn concurrent_publishers_get_distinct_monotonic_versions() {
    let registry = toad::coordinator::ModelRegistry::new();
    let versions: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for t in 0..4 {
            let registry = &registry;
            let versions = &versions;
            s.spawn(move || {
                for i in 0..8 {
                    let (model, _) = constant_model((t * 8 + i) as f64);
                    let key = format!("k{t}");
                    let dep = registry.publish(&key, card("c", 0.5), model.quantize());
                    versions.lock().unwrap().push(dep.version);
                }
            });
        }
    });
    let mut vs = versions.into_inner().unwrap();
    vs.sort_unstable();
    let n = vs.len();
    vs.dedup();
    assert_eq!(vs.len(), n, "versions must be unique across concurrent publishers");
    assert_eq!(registry.latest_version(), 32);
    assert_eq!(registry.len(), 4);
}

/// Racing publishes to the *same* key must never leave an older
/// version live: the registry assigns the version inside the write
/// critical section, so the deployment installed last always carries
/// the highest version and any observer sees `version_of` only move
/// forward. (Regression test: versions used to be drawn before the
/// lock, letting a preempted publisher overwrite a newer one.)
#[test]
fn racing_publishes_to_one_key_never_regress_the_live_version() {
    let registry = toad::coordinator::ModelRegistry::new();
    let (seed, _) = constant_model(0.0);
    registry.publish("m", card("c", 0.5), seed.quantize());
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        // Watcher: the live version must be non-decreasing throughout
        // the whole publish race.
        let registry_ref = &registry;
        let stop_ref = &stop;
        let watcher = s.spawn(move || {
            let mut last = 0u64;
            while !stop_ref.load(Ordering::Acquire) {
                let v = registry_ref.version_of("m").expect("key stays published");
                assert!(v >= last, "live version regressed: {last} -> {v}");
                last = v;
            }
            // One unconditional sample after observing the stop flag:
            // the Acquire load synchronizes with the Release store that
            // runs only once every publisher has joined, so this sample
            // is guaranteed (even if the loop body never ran) and must
            // see the highest version ever installed.
            let v = registry_ref.version_of("m").expect("key stays published");
            assert!(v >= last, "live version regressed: {last} -> {v}");
            v
        });
        // Inner scope joins all publishers before the watcher is told
        // to stop, so it samples across the entire race window.
        std::thread::scope(|inner| {
            for t in 0..4 {
                let registry = &registry;
                inner.spawn(move || {
                    for i in 0..16 {
                        let (model, _) = constant_model((t * 16 + i) as f64);
                        registry.publish("m", card("c", 0.5), model.quantize());
                    }
                });
            }
        });
        stop.store(true, Ordering::Release);
        let last = watcher.join().expect("watcher must not panic");
        assert_eq!(last, 65, "watcher's final sample must be the final version");
    });
    // 1 seed + 64 racing publishes; the final live version is the
    // highest ever assigned — nothing older stayed installed.
    assert_eq!(registry.version_of("m"), Some(65));
    assert_eq!(registry.latest_version(), 65);
}
