//! Bit-parity suite for the sparse CSR pipeline.
//!
//! The claims under test (see `gbdt::histogram` and `data::binning`
//! module docs):
//!
//! * `Binner::fit_sparse` boundaries are bit-equal to `Binner::fit` on
//!   the densified input, and `bin_sparse` equals `bin_matrix` on the
//!   densified input cell for cell — universally, NaN-valued present
//!   entries and explicit zeros included;
//! * the nnz-scaled histogram kernel (present-entry accumulation + one
//!   closed-form default-bin correction) is bit-identical across every
//!   (SIMD tier, feature-shard count) combination, and coincides bit
//!   for bit with the densified kernel on integer-exact statistics —
//!   so one-round training on balanced ±1 targets produces the same
//!   model bits sparse as densified, over every density, code width,
//!   shard count, and row-worker count;
//! * multi-round sparse training (arbitrary float statistics) is
//!   invariant within its family: the same model bits for every
//!   feature-shard count, and for every row-worker count `K ≥ 1`;
//! * sparse columnar inference (`predict_batch_columns_sparse`) equals
//!   dense columnar inference on the densified input bit for bit, on
//!   every tier, NaN present entries included — and `score_sparse`
//!   therefore equals `Predictor::score` on the densified test set;
//! * a present NaN is *not* an absent entry: it routes to the top bin,
//!   never the feature's default bin.

use toad::data::binning::Binner;
use toad::data::synth::synth_sparse_rows;
use toad::data::{
    train_test_split_sparse, CsrMatrix, SparseDataset, Task, SPARSE_DENSITY_THRESHOLD,
};
use toad::gbdt::{GbdtModel, Node};

/// Exact structural bits of a model: every tree node's discriminant and
/// payload with floats as raw bits, plus the base scores. Two models
/// compare equal here iff training made identical decisions *and*
/// identical arithmetic.
fn model_bits(m: &GbdtModel) -> Vec<u64> {
    let mut out: Vec<u64> = m.base_scores.iter().map(|b| b.to_bits()).collect();
    for stream in &m.trees {
        out.push(stream.len() as u64);
        for tree in stream {
            out.push(tree.nodes.len() as u64);
            for node in &tree.nodes {
                match *node {
                    Node::Internal { feature, bin, threshold, left, right } => {
                        out.push(0);
                        out.push(feature as u64);
                        out.push(bin as u64);
                        out.push(threshold.to_bits() as u64);
                        out.push(left as u64);
                        out.push(right as u64);
                    }
                    Node::Leaf { value } => {
                        out.push(1);
                        out.push(value.to_bits());
                    }
                }
            }
        }
    }
    out
}

fn sparse_dataset(seed: u64, n: usize, nf: usize, density: f64) -> SparseDataset {
    let (x, targets) = synth_sparse_rows(seed, 0..n, nf, density);
    SparseDataset {
        name: "synth_sparse".into(),
        x,
        targets,
        labels: vec![],
        task: Task::Regression,
    }
}

/// A deterministic sparse dataset with *integer-exact* statistics:
/// small-integer feature values (feature 0 wide enough to force a u16
/// arena at max_bins 400), balanced ±1 regression targets (⇒ base
/// score exactly 0.0, round-1 gradients ±1, hessians 1), and a
/// `density_pct`-percent presence rule. On these, every histogram sum
/// is integer-valued, so f64 addition is associative and the sparse
/// default-bin correction `T − P` is exact.
fn int_sparse(n: usize, nf: usize, density_pct: usize) -> SparseDataset {
    assert!(n % 2 == 0, "balanced targets need even n");
    let mut x = CsrMatrix::empty(nf);
    let mut row: Vec<(u32, f32)> = Vec::new();
    for i in 0..n {
        row.clear();
        for f in 0..nf {
            if (i * 31 + f * 17 + 7) % 100 < density_pct {
                let v = if f == 0 {
                    ((i * 7) % 300) as f32 - 150.0
                } else {
                    ((i + 2 * f) % 7) as f32 - 3.0 // includes explicit 0.0
                };
                row.push((f as u32, v));
            }
        }
        x.push_row(&row);
    }
    let targets: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    SparseDataset { name: "int_sparse".into(), x, targets, labels: vec![], task: Task::Regression }
}

fn bits2(scores: &[Vec<f64>]) -> Vec<u64> {
    scores.iter().flat_map(|r| r.iter().map(|v| v.to_bits())).collect()
}

#[cfg(not(miri))]
mod training {
    use super::*;
    use toad::data::BinMatrix;
    use toad::gbdt::booster::train;
    use toad::gbdt::histogram::HistogramSet;
    use toad::gbdt::{train_sparse, GbdtParams};
    use toad::inference::Predictor;

    fn params(max_bins: usize) -> GbdtParams {
        GbdtParams { max_bins, ..GbdtParams::paper(1, 3) }
    }

    /// Tentpole claim: on integer-exact statistics, sparse training is
    /// bit-identical to training the dense pipeline on the densified
    /// input — for every density (sparse-stored, mixed, dense-stored),
    /// both code widths, every feature-shard count, and row-sharded
    /// reduction too. The root leaf has ≥ SHARD_MIN_ROWS rows, so the
    /// sharded paths genuinely engage.
    #[test]
    fn sparse_training_matches_densified_on_integer_stats() {
        let n = 5000;
        for density_pct in [1usize, 20, 90] {
            let sds = int_sparse(n, 8, density_pct);
            let dense = sds.densify();
            for max_bins in [255usize, 400] {
                for (shards, workers) in [(1usize, 0usize), (3, 0), (1, 2), (3, 3)] {
                    let p = GbdtParams {
                        histogram_shards: shards,
                        row_workers: workers,
                        ..params(max_bins)
                    };
                    let want = model_bits(&train(&dense, p));
                    let got = model_bits(&train_sparse(&sds, p));
                    assert_eq!(
                        want, got,
                        "density={density_pct}% max_bins={max_bins} \
                         shards={shards} workers={workers}"
                    );
                }
            }
        }
    }

    /// Within the sparse family, multi-round training on arbitrary
    /// float statistics is invariant: every feature-shard count trains
    /// the same bits as the sequential build, and every row-worker
    /// count `K ≥ 1` trains the same bits as `K = 1` (the `K = 0` /
    /// `K ≥ 1` last-ulp split is the same documented contract as the
    /// dense pipeline's).
    #[test]
    fn sparse_training_invariant_across_shards_and_workers() {
        let sds = sparse_dataset(29, 5000, 24, 0.05);
        let p0 = GbdtParams { max_bins: 255, ..GbdtParams::paper(4, 3) };
        let want = model_bits(&train_sparse(&sds, GbdtParams { histogram_shards: 1, ..p0 }));
        for shards in [2usize, 3, 8] {
            let got = model_bits(&train_sparse(&sds, GbdtParams { histogram_shards: shards, ..p0 }));
            assert_eq!(want, got, "shards={shards}");
        }
        let w1 = model_bits(&train_sparse(&sds, GbdtParams { row_workers: 1, ..p0 }));
        for workers in [2usize, 3] {
            let got = model_bits(&train_sparse(&sds, GbdtParams { row_workers: workers, ..p0 }));
            assert_eq!(w1, got, "row_workers={workers}");
        }
    }

    fn hist_bits(h: &HistogramSet, bins: &[usize]) -> Vec<u64> {
        let mut out = Vec::new();
        for (f, &nb) in bins.iter().enumerate() {
            for b in 0..nb {
                let (g, h_, c) = h.bin(f, b);
                out.extend([g.to_bits(), h_.to_bits(), c as u64]);
            }
        }
        out
    }

    /// The sparse kernel over a real mixed `bin_sparse` arena is
    /// bit-identical across every (SIMD tier, shard count) combination,
    /// on arbitrary float statistics, for full-leaf and subset row
    /// sets.
    #[test]
    fn sparse_histogram_kernel_is_tier_and_shard_invariant() {
        let n = 3000;
        let sds = sparse_dataset(31, n, 10, 0.08);
        let binner = Binner::fit_sparse(&sds, 255);
        let binned = binner.bin_sparse(&sds.x);
        assert!(binned.has_sparse(), "fixture must exercise sparse columns");
        let bins: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
        let grad: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let hess: Vec<f64> = (0..n).map(|i| 0.5 + ((i * 13) % 7) as f64 * 0.1).collect();
        let full: Vec<u32> = (0..n as u32).collect();
        let subset: Vec<u32> = (0..n as u32).filter(|i| i % 5 != 2).collect();
        for rows in [&full, &subset] {
            let mut want: Option<Vec<u64>> = None;
            for tier in toad::simd::available_tiers() {
                for shards in [1usize, 2, 5] {
                    let mut h = HistogramSet::new(&bins);
                    h.build_sharded_with_tier(&binned, rows, &grad, &hess, shards, tier);
                    let got = hist_bits(&h, &bins);
                    match &want {
                        None => want = Some(got),
                        Some(w) => assert_eq!(
                            w,
                            &got,
                            "tier={tier:?} shards={shards} rows={}",
                            rows.len()
                        ),
                    }
                }
            }
        }
    }

    /// End-to-end histogram cross-check on integer statistics: the
    /// sparse kernel over `bin_sparse` equals the scalar oracle over
    /// `bin_matrix(densify)` bit for bit (both matrices are cell-equal;
    /// on integer stats the add regrouping is exact).
    #[test]
    fn sparse_histogram_matches_densified_oracle_on_integer_stats() {
        let n = 1200;
        let sds = int_sparse(n, 6, 10);
        let binner = Binner::fit_sparse(&sds, 255);
        let sparse_binned = binner.bin_sparse(&sds.x);
        assert!(sparse_binned.has_sparse());
        let dense_binned = binner.bin_matrix(&sds.densify());
        let bins: Vec<usize> = (0..binner.n_features()).map(|f| binner.n_bins(f)).collect();
        let grad: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let hess = vec![1.0; n];
        let rows: Vec<u32> = (0..n as u32).filter(|i| i % 3 != 1).collect();
        let mut oracle = HistogramSet::new(&bins);
        oracle.build_scalar(&dense_binned, &rows, &grad, &hess);
        for tier in toad::simd::available_tiers() {
            for shards in [1usize, 3] {
                let mut h = HistogramSet::new(&bins);
                h.build_sharded_with_tier(&sparse_binned, &rows, &grad, &hess, shards, tier);
                assert_eq!(
                    hist_bits(&oracle, &bins),
                    hist_bits(&h, &bins),
                    "tier={tier:?} shards={shards}"
                );
            }
        }
    }

    /// Sparse binning equals densified binning cell for cell —
    /// universally (no integer-stat caveat), NaN present entries
    /// included — and the storage choice follows the density
    /// threshold.
    #[test]
    fn bin_sparse_matches_densified_binning_with_nans() {
        for density in [0.01f64, 0.2, 0.9] {
            let mut sds = sparse_dataset(37, 1500, 12, density);
            for k in (0..sds.x.values.len()).step_by(53) {
                sds.x.values[k] = f32::NAN;
            }
            let dense = sds.densify();
            for max_bins in [16usize, 255, 400] {
                let want_binner = Binner::fit(&dense, max_bins);
                let binner = Binner::fit_sparse(&sds, max_bins);
                for f in 0..binner.n_features() {
                    assert_eq!(want_binner.n_bins(f), binner.n_bins(f), "f={f}");
                    for b in 0..binner.n_bins(f).saturating_sub(1) {
                        assert_eq!(
                            want_binner.threshold_value(f, b).to_bits(),
                            binner.threshold_value(f, b).to_bits(),
                            "density={density} max_bins={max_bins} f={f} boundary {b}"
                        );
                    }
                }
                let ms: BinMatrix = binner.bin_sparse(&sds.x);
                assert_eq!(
                    ms.has_sparse(),
                    density < SPARSE_DENSITY_THRESHOLD,
                    "storage choice at density {density}"
                );
                let md = want_binner.bin_matrix(&dense);
                assert_eq!(
                    ms.to_row_major(),
                    md.to_row_major(),
                    "density={density} max_bins={max_bins}"
                );
            }
        }
    }

    /// Sparse columnar inference equals dense columnar inference on the
    /// densified input bit for bit, on every tier, NaN present entries
    /// included — `score`/sweeps/gateway can serve sparse datasets
    /// through the same descent kernels unchanged.
    #[test]
    fn sparse_columnar_inference_matches_dense_bit_for_bit() {
        for density in [0.01f64, 0.2, 0.9] {
            let mut sds = sparse_dataset(41, 2000, 12, density);
            for k in (0..sds.x.values.len()).step_by(97) {
                sds.x.values[k] = f32::NAN;
            }
            let model = train_sparse(&sds, GbdtParams::paper(8, 3));
            let quant = model.quantize();
            let dense = sds.densify();
            let cols: Vec<&[f32]> = dense.features.iter().map(|c| c.as_slice()).collect();
            let want = bits2(&quant.predict_batch_columns(&cols, sds.n_rows()));
            for tier in toad::simd::available_tiers() {
                let got = bits2(&quant.predict_batch_columns_sparse_with_tier(&sds.x, tier));
                assert_eq!(want, got, "density={density} tier={tier:?}");
            }
        }
    }

    /// `score_sparse` computes the identical metric (same predictions,
    /// same fold) as `Predictor::score` on the densified test set.
    #[test]
    fn score_sparse_equals_dense_score() {
        let sds = sparse_dataset(43, 1500, 10, 0.1);
        let (tr, te) = train_test_split_sparse(&sds, 0.2, 5);
        let model = train_sparse(&tr, GbdtParams::paper(8, 3));
        let quant = model.quantize();
        let want = quant.score(&te.densify());
        assert_eq!(want.to_bits(), quant.score_sparse(&te).to_bits());
    }
}

// ---------------------------------------------------------------------
// Miri-runnable: pure binning semantics, no training.
// ---------------------------------------------------------------------

/// A present NaN is not an absent entry: it bins to the top bin (routes
/// right at every split), while absent rows read the feature's default
/// bin — the bin of the implicit 0.0.
#[test]
fn nan_present_entry_routes_to_top_bin_not_default() {
    let mut x = CsrMatrix::empty(1);
    x.push_row(&[(0, 1.0)]);
    x.push_row(&[(0, 2.0)]);
    x.push_row(&[(0, 3.0)]);
    x.push_row(&[(0, f32::NAN)]);
    x.push_row(&[]); // absent ⇒ implicit 0.0
    x.push_row(&[(0, -1.0)]);
    let sds = SparseDataset {
        name: "nan_vs_absent".into(),
        x,
        targets: vec![0.0; 6],
        labels: vec![],
        task: Task::Regression,
    };
    let binner = Binner::fit_sparse(&sds, 16);
    let binned = binner.bin_sparse(&sds.x);
    let top = (binner.n_bins(0) - 1) as u16;
    let default = binner.default_bin(0);
    assert_ne!(top, default);
    assert_eq!(binned.bin(0, 3), top, "present NaN takes the top bin");
    assert_eq!(binned.bin(0, 4), default, "absent entry takes the default bin");
    // Distinct values {-1, 0 (implicit), 1, 2, 3} ⇒ 0.0 is interior,
    // not bin 0: absent ≠ "smallest".
    assert_ne!(default, 0);
}
